//! Fleet-scale cluster simulation: the paper's core specialization
//! lifted one level up, from cores to machines.
//!
//! The paper confines AVX code to a subset of *cores* so only those
//! cores' frequency drops. At datacenter scale the same variability
//! becomes a fleet-wide straggler problem (Schuchart et al.: performance
//! *variation* dominates once you aggregate over many nodes), and the
//! policy question generalizes: route AVX-heavy request streams to a
//! subset of *machines*, and the scalar majority of the fleet never
//! sees a wide instruction — the router analogue of `with_avx()` plus
//! `PolicyKind::CoreSpec`.
//!
//! * [`router`] — the pluggable front-end policies ([`RouterSpec`] /
//!   [`Router`]): round-robin, least-outstanding (estimated-backlog
//!   JSQ), and the headline AVX partition.
//! * [`cluster`] — [`FleetCfg`] + [`run_fleet`]: demultiplex one seeded
//!   arrival stream into per-machine traces, simulate every machine
//!   independently (parallel across OS threads, byte-identical at any
//!   thread count), and merge per-machine [`LatencyStats`] into
//!   cluster-wide tails. A fleet of size 1 reproduces the standalone
//!   web-server run bit for bit (`rust/tests/fleet.rs` pins both
//!   properties).
//!
//! Consumers: the scenario matrix sweeps fleet-size × router as
//! first-class axes, `metrics::fleet_report` renders per-machine and
//! cluster rows, `avxfreq fleet` runs one fleet from flags or
//! `configs/fleet_slo.toml`, and `repro fleetvar` restates Fig 5 as
//! cross-machine p99 variance under round-robin vs AVX-aware routing.
//!
//! [`LatencyStats`]: crate::traffic::LatencyStats

pub mod cluster;
pub mod router;

pub use cluster::{route_stream, run_fleet, FleetCfg, FleetRun};
pub use router::{Router, RouterSpec};
