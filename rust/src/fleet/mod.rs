//! Fleet-scale cluster simulation: the paper's core specialization
//! lifted one level up, from cores to machines.
//!
//! The paper confines AVX code to a subset of *cores* so only those
//! cores' frequency drops. At datacenter scale the same variability
//! becomes a fleet-wide straggler problem (Schuchart et al.: performance
//! *variation* dominates once you aggregate over many nodes), and the
//! policy question generalizes: route AVX-heavy request streams to a
//! subset of *machines*, and the scalar majority of the fleet never
//! sees a wide instruction — the router analogue of `with_avx()` plus
//! `PolicyKind::CoreSpec`.
//!
//! * [`router`] — the pluggable front-end policies ([`RouterSpec`] /
//!   [`Router`]): round-robin, least-outstanding (estimated-backlog
//!   JSQ), and the headline AVX partition.
//! * [`cluster`] — [`FleetCfg`] + [`run_fleet`]: demultiplex one seeded
//!   arrival stream into per-machine traces, simulate every machine
//!   independently (parallel across OS threads, byte-identical at any
//!   thread count), and merge per-machine [`LatencyStats`] into
//!   cluster-wide tails. A fleet of size 1 reproduces the standalone
//!   web-server run bit for bit (`rust/tests/fleet.rs` pins both
//!   properties).
//!
//! * [`hierarchy`] — machine → rack → cluster aggregation that *streams*:
//!   each machine's recorder merges into its rack's and the cluster's
//!   [`LatencyStats`] the moment the machine finishes, then the
//!   per-machine run is dropped. A 1000-machine sweep holds O(machines)
//!   scalar digests plus O(racks + 1) histograms — never a vector of
//!   retained `WebRun`s.
//! * [`balancer`] — the closed-loop front-end: per-request timeouts with
//!   seeded retry-with-backoff, hedged requests after a p99-based delay,
//!   and a health view that ejects slow machines. Feedback is
//!   epoch-based (epoch *k + 1* is routed from epoch *k*'s merged
//!   statistics), which is what lets the closed loop keep the
//!   byte-identical-at-any-thread-count determinism contract; the
//!   feedback-disabled configuration reproduces the open-loop bytes
//!   exactly (differential-tested in `rust/tests/hierfleet.rs`).
//!
//! Consumers: the scenario matrix sweeps fleet-size × router × balancer
//! as first-class axes, `metrics::fleet_report` / `metrics::hier_report`
//! render per-machine, per-rack, and cluster rows, `avxfreq fleet` runs
//! one fleet from flags or `configs/fleet_slo.toml` /
//! `configs/fleet_closed.toml`, `repro fleetvar` restates Fig 5 as
//! cross-machine p99 variance under round-robin vs AVX-aware routing,
//! and `repro fleetscale` shows AVX-induced variation amplifying with
//! fleet size under a bulk-synchronous collective.
//!
//! [`LatencyStats`]: crate::traffic::LatencyStats

pub mod balancer;
pub mod cluster;
pub mod hierarchy;
pub mod router;

pub use balancer::{run_hier_fleet, BalancerCfg, HierFleetCfg};
pub use cluster::{
    route_stream, run_fleet, service_est_ns, FleetCfg, FleetRun, DEFAULT_SERVICE_EST_US,
};
pub use hierarchy::{
    collective_makespan, CollectiveSummary, HierFleetRun, HierarchyAgg, MachineDigest,
};
pub use router::{Router, RouterSpec};
