//! Cluster front-end request routers.
//!
//! A [`Router`] demultiplexes the fleet's shared arrival stream over N
//! machines *before* any machine simulates — routing is a pure function
//! of the arrival stream and the router's own bookkeeping, never of
//! simulated machine state. That is what lets the fleet run every
//! machine as an independent, embarrassingly-parallel simulation while
//! staying byte-identical at any thread count (the same property the
//! scenario matrix has). Real cluster front-ends are in the same boat:
//! they act on arrival-side and stale/estimated signals, not on the
//! ground-truth queue depth inside every server.

use crate::sim::Time;

/// Declarative router selection (the matrix/config-facing side of the
/// fleet's routing axis); [`RouterSpec::build`] instantiates the
/// stateful [`Router`] for a concrete fleet size.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum RouterSpec {
    /// Cycle through the machines in index order.
    RoundRobin,
    /// Send each arrival to the machine with the smallest estimated
    /// backlog, modelled as a single server working off routed requests
    /// at a nominal `service_est` nanoseconds each (join-the-shortest-
    /// estimated-queue; ties go to the lowest index).
    LeastOutstanding { service_est: Time },
    /// The paper's `CoreSpec` lifted to datacenter scale: requests from
    /// AVX-carrying tenants are pinned to the *last* `avx_machines`
    /// machines (mirroring how `PolicyKind::CoreSpec` reserves the last
    /// cores of a socket), round-robin within each subset. The scalar
    /// majority of the fleet never receives a single wide instruction,
    /// so — exactly like the paper's scalar cores — those machines keep
    /// their full clock.
    AvxPartition { avx_machines: usize },
}

impl RouterSpec {
    /// Least-outstanding with the default 300 µs per-request service
    /// estimate (the order of one paper-sized request).
    pub fn least_outstanding() -> RouterSpec {
        RouterSpec::LeastOutstanding { service_est: 300_000 }
    }

    /// Short label used in tables and cell identifiers.
    pub fn label(&self) -> String {
        match self {
            RouterSpec::RoundRobin => "round-robin".to_string(),
            RouterSpec::LeastOutstanding { .. } => "least-out".to_string(),
            RouterSpec::AvxPartition { avx_machines } => format!("avx-part({avx_machines})"),
        }
    }

    /// Parse a CLI/config router name; `avx_machines` parameterizes the
    /// partition router and `service_est` (ns per request) the
    /// least-outstanding backlog estimate. Non-positive estimates are
    /// rejected here — previously `parse` silently discarded the tuning
    /// and always returned the hardcoded 300 µs default.
    pub fn parse(name: &str, avx_machines: usize, service_est: Time) -> anyhow::Result<RouterSpec> {
        Ok(match name {
            "round-robin" | "rr" => RouterSpec::RoundRobin,
            "least-outstanding" | "least-out" => {
                anyhow::ensure!(
                    service_est > 0,
                    "least-outstanding service estimate must be positive (got {service_est} ns)"
                );
                RouterSpec::LeastOutstanding { service_est }
            }
            "avx-partition" | "avx-part" => RouterSpec::AvxPartition { avx_machines },
            other => anyhow::bail!(
                "unknown router {other:?} (round-robin|least-outstanding|avx-partition)"
            ),
        })
    }

    /// Instantiate the stateful router for a fleet of `machines`.
    pub fn build(&self, machines: usize) -> Router {
        let n = machines.max(1);
        let state = match *self {
            RouterSpec::RoundRobin => RouterState::RoundRobin { next: 0 },
            RouterSpec::LeastOutstanding { service_est } => RouterState::LeastOutstanding {
                service_est: service_est.max(1),
                next_free: vec![0; n],
            },
            RouterSpec::AvxPartition { avx_machines } => {
                // Defensive clamp into [1, n-1] so both subsets exist on
                // any fleet that can be partitioned at all; a fleet of 1
                // routes everything to machine 0 regardless.
                // `FleetCfg::validate` rejects out-of-range subsets
                // before a fleet run ever gets here, so the clamp can
                // only fire for hand-built routers (e.g. unit tests) —
                // never silently behind a reported label.
                let k = if n == 1 { 0 } else { avx_machines.clamp(1, n - 1) };
                RouterState::AvxPartition { avx_machines: k, scalar_next: 0, avx_next: 0 }
            }
        };
        Router { n, state }
    }
}

/// Stateful per-run router: see [`RouterSpec`] for the policies.
///
/// The AVX-partition policy is the router analogue of the paper's core
/// specialization — `with_avx()` tags a *thread* so the scheduler keeps
/// wide instructions on dedicated cores; the AVX tenant flag tags a
/// *request stream* so the front-end keeps wide instructions on
/// dedicated machines. Both confine the frequency reduction to a known
/// subset instead of letting it roam the whole resource pool.
#[derive(Clone, Debug)]
pub struct Router {
    n: usize,
    state: RouterState,
}

#[derive(Clone, Debug)]
enum RouterState {
    RoundRobin { next: usize },
    LeastOutstanding { service_est: Time, next_free: Vec<Time> },
    AvxPartition { avx_machines: usize, scalar_next: usize, avx_next: usize },
}

impl Router {
    /// Fleet size this router was built for.
    pub fn machines(&self) -> usize {
        self.n
    }

    /// Route one arrival at time `at` (ns); `avx` is whether the
    /// arrival's tenant carries AVX work. Returns a machine index in
    /// `[0, machines)`.
    pub fn route(&mut self, at: Time, avx: bool) -> usize {
        let n = self.n;
        match &mut self.state {
            RouterState::RoundRobin { next } => {
                let pick = *next;
                *next = (*next + 1) % n;
                pick
            }
            RouterState::LeastOutstanding { service_est, next_free } => {
                let (pick, _) = next_free
                    .iter()
                    .copied()
                    .enumerate()
                    .min_by_key(|&(i, free)| (free.saturating_sub(at), i))
                    .expect("fleet has at least one machine");
                next_free[pick] = next_free[pick].max(at).saturating_add(*service_est);
                pick
            }
            RouterState::AvxPartition { avx_machines, scalar_next, avx_next } => {
                let k = *avx_machines;
                if k == 0 {
                    // Fleet of 1: no partition to apply.
                    return 0;
                }
                if avx {
                    let pick = n - k + *avx_next;
                    *avx_next = (*avx_next + 1) % k;
                    pick
                } else {
                    let pick = *scalar_next;
                    *scalar_next = (*scalar_next + 1) % (n - k);
                    pick
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_cycles() {
        let mut r = RouterSpec::RoundRobin.build(3);
        let picks: Vec<usize> = (0..7).map(|i| r.route(i as Time, i % 2 == 0)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2, 0]);
    }

    #[test]
    fn avx_partition_pins_avx_to_last_machines() {
        let mut r = RouterSpec::AvxPartition { avx_machines: 2 }.build(5);
        for i in 0..20 {
            let m = r.route(i as Time, true);
            assert!(m >= 3, "avx arrival routed to scalar machine {m}");
        }
        for i in 0..20 {
            let m = r.route(i as Time, false);
            assert!(m < 3, "scalar arrival routed to avx machine {m}");
        }
    }

    #[test]
    fn avx_partition_clamps_subset() {
        // Oversized subset clamps so a scalar subset always exists.
        let mut r = RouterSpec::AvxPartition { avx_machines: 9 }.build(3);
        assert_eq!(r.route(0, false), 0);
        assert!(r.route(1, true) >= 1);
        // A fleet of 1 routes everything to machine 0.
        let mut one = RouterSpec::AvxPartition { avx_machines: 2 }.build(1);
        assert_eq!(one.route(0, true), 0);
        assert_eq!(one.route(1, false), 0);
    }

    #[test]
    fn least_outstanding_prefers_idle_machines() {
        let mut r = RouterSpec::least_outstanding().build(2);
        // Both idle at t=0: lowest index wins, then the other.
        assert_eq!(r.route(0, false), 0);
        assert_eq!(r.route(0, false), 1);
        // Far in the future both backlogs have drained: index 0 again.
        assert_eq!(r.route(10_000_000, false), 0);
    }

    #[test]
    fn parse_names() {
        let est = 300_000; // default 300 µs estimate, in ns
        assert_eq!(RouterSpec::parse("rr", 1, est).unwrap(), RouterSpec::RoundRobin);
        assert_eq!(
            RouterSpec::parse("avx-partition", 2, est).unwrap(),
            RouterSpec::AvxPartition { avx_machines: 2 }
        );
        assert!(matches!(
            RouterSpec::parse("least-outstanding", 1, est).unwrap(),
            RouterSpec::LeastOutstanding { .. }
        ));
        assert!(RouterSpec::parse("random", 1, est).is_err());
    }

    #[test]
    fn parse_threads_service_estimate_through() {
        // Regression: parse used to ignore the tuning and always hand
        // back the hardcoded 300 µs estimate.
        assert_eq!(
            RouterSpec::parse("least-outstanding", 1, 50_000).unwrap(),
            RouterSpec::LeastOutstanding { service_est: 50_000 }
        );
        assert_eq!(
            RouterSpec::parse("least-out", 1, 2_000_000).unwrap(),
            RouterSpec::LeastOutstanding { service_est: 2_000_000 }
        );
        // Non-positive estimates are rejected, not silently clamped.
        assert!(RouterSpec::parse("least-outstanding", 1, 0).is_err());
        // The estimate is irrelevant to (and ignored by) other routers.
        assert_eq!(RouterSpec::parse("rr", 1, 0).unwrap(), RouterSpec::RoundRobin);
    }
}
