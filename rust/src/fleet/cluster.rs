//! Fleet simulation: N independent [`Machine`]s behind one routed
//! arrival stream, with cross-machine latency aggregation.
//!
//! [`run_fleet`] proceeds in three phases:
//!
//! 1. **Demultiplex** — the cluster's arrival stream is generated once
//!    from the fleet seed (`seed ^ 0xDEAD`, the same derivation a
//!    standalone [`run_webserver`] uses) and split into per-machine
//!    `(time, tenant)` traces by the [`Router`]. Routing sees only the
//!    stream and the router's own bookkeeping, so the split is a pure
//!    function of the fleet configuration.
//! 2. **Simulate** — each machine replays its trace through
//!    [`crate::workload::webserver::run_webserver_trace`] on whatever OS
//!    thread claims it (atomic-cursor work stealing, results keyed by
//!    machine index). Machine 0 keeps the fleet seed — which is why a
//!    fleet of size 1 is *byte-identical* to the standalone run — and
//!    further machines fork decorrelated seeds.
//! 3. **Aggregate** — per-machine [`LatencyStats`] recorders are
//!    [`LatencyStats::merge`]d (histogram buckets and exact SLO counters
//!    add) into cluster-wide tails. Percentiles are merged at the
//!    histogram level, never averaged: a p99 of p99s is not the fleet
//!    p99.
//!
//! [`Machine`]: crate::sched::machine::Machine
//! [`run_webserver`]: crate::workload::webserver::run_webserver

use super::router::{Router, RouterSpec};
use crate::sim::{Time, SEC};
use crate::traffic::{ArrivalGen, LatencyStats, TailSummary};
use crate::util::{mix64, Summary};
use crate::workload::webserver::{run_webserver_trace, WebCfg, WebRun};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Fleet configuration: N machines stamped from one [`WebCfg`] template
/// behind a [`RouterSpec`] front-end.
#[derive(Clone, Debug)]
pub struct FleetCfg {
    /// Number of machines behind the front-end.
    pub machines: usize,
    /// Routing policy demultiplexing the shared arrival stream.
    pub router: RouterSpec,
    /// Per-machine template. `cfg.mode` carries the *fleet-total*
    /// open-loop arrival process (per-machine load emerges from
    /// routing), and `cfg.seed` doubles as the fleet seed.
    pub cfg: WebCfg,
}

impl FleetCfg {
    pub fn new(machines: usize, router: RouterSpec, cfg: WebCfg) -> Self {
        FleetCfg { machines: machines.max(1), router, cfg }
    }

    /// Build a fleet from a TOML config: the `[machine]`/`[server]`/
    /// `[sched]`/`[load]` sections describe the per-machine template
    /// exactly as for `avxfreq sim` (with `load.rate` as the
    /// fleet-total offered rate), plus:
    ///
    /// ```toml
    /// [fleet]
    /// machines = 6
    /// router = "avx-partition"   # round-robin | least-outstanding | avx-partition
    /// avx_machines = 1           # size of the AVX subset (partition router)
    /// service_est_us = 300.0     # least-outstanding per-request estimate (µs)
    /// ```
    pub fn from_config(conf: &crate::util::config::Config) -> anyhow::Result<FleetCfg> {
        let cfg = WebCfg::from_config(conf)?;
        let machines = conf.usize_or("fleet.machines", 4).max(1);
        let avx_machines = conf.usize_or("fleet.avx_machines", 1);
        let service_est = service_est_from_config(conf)?;
        let router =
            RouterSpec::parse(conf.str_or("fleet.router", "round-robin"), avx_machines, service_est)?;
        let fleet = FleetCfg { machines, router, cfg };
        fleet.validate()?;
        Ok(fleet)
    }

    /// Reject configurations the fleet cannot demultiplex — or would
    /// demultiplex into silently nonsensical output.
    pub fn validate(&self) -> anyhow::Result<()> {
        let process = self.cfg.mode.process();
        anyhow::ensure!(
            process.is_some(),
            "a fleet needs an open-loop arrival stream to route (closed-loop \
             connections live inside one machine)"
        );
        // A fleet of 1 is the single-machine differential anchor and
        // routes everything to machine 0 under any router; only real
        // partitions need the shape checks.
        if self.machines > 1 {
            if let RouterSpec::AvxPartition { avx_machines } = self.router {
                anyhow::ensure!(
                    (1..self.machines).contains(&avx_machines),
                    "fleet.avx_machines = {avx_machines} must leave both subsets non-empty \
                     (1..={} for {} machines) — a silent clamp would make the reported \
                     router label lie about the routing that ran",
                    self.machines - 1,
                    self.machines
                );
                let p = process.expect("checked above");
                anyhow::ensure!(
                    (0..p.n_tenants()).any(|i| !p.tenant_carries_avx(i)),
                    "avx-partition needs a multi-tenant mix with a non-AVX tenant \
                     (load.process = \"mix\" or \"bursty-mix\"): a single-stream process \
                     counts as AVX-carrying, so 100% of traffic would land on the AVX \
                     subset and the idle machines would fake the dispersion metrics"
                );
            }
        }
        Ok(())
    }

    /// Seed for machine `i`: machine 0 keeps the fleet seed (a fleet of
    /// size 1 *is* the standalone run), further machines fork via a
    /// SplitMix64 finalizer so their worker RNG streams decorrelate.
    pub fn machine_seed(&self, i: usize) -> u64 {
        if i == 0 {
            self.cfg.seed
        } else {
            mix64(self.cfg.seed ^ (i as u64).wrapping_mul(0xA24B_AED4_963E_E407))
        }
    }
}

/// Default least-outstanding per-request service estimate (µs) — the
/// order of one paper-sized request; see [`RouterSpec::least_outstanding`].
pub const DEFAULT_SERVICE_EST_US: f64 = 300.0;

/// Convert a `service_est_us` microsecond figure (config/CLI) into the
/// router's nanosecond estimate, rejecting non-positive or non-finite
/// values before they could silently clamp inside the router.
pub fn service_est_ns(us: f64) -> anyhow::Result<Time> {
    anyhow::ensure!(
        us.is_finite() && us > 0.0,
        "fleet service estimate must be a positive number of microseconds (got {us})"
    );
    Ok((us * 1000.0).round().max(1.0) as Time)
}

fn service_est_from_config(conf: &crate::util::config::Config) -> anyhow::Result<Time> {
    service_est_ns(conf.float_or("fleet.service_est_us", DEFAULT_SERVICE_EST_US))
}

/// Results of one fleet run: per-machine [`WebRun`]s plus cluster-wide
/// merged aggregates.
#[derive(Clone, Debug)]
pub struct FleetRun {
    /// Router label (see [`RouterSpec::label`]).
    pub router: String,
    /// Per-machine results, in machine-index order.
    pub machines: Vec<WebRun>,
    /// Arrivals the router sent to each machine (whole run, including
    /// warmup — routing does not know about measurement windows).
    pub arrivals_routed: Vec<u64>,
    /// Cluster-wide recorder: every machine's aggregate
    /// [`LatencyStats`] merged.
    pub stats: LatencyStats,
    /// Cluster-wide tail summary frozen from [`FleetRun::stats`].
    pub tail: TailSummary,
    /// Cluster-wide per-tenant recorders (merged across machines), in
    /// tenant-index order with their labels.
    pub tenant_stats: Vec<(String, LatencyStats)>,
    /// Total completions in the measurement window.
    pub completed: u64,
    /// Total arrivals dropped by machine overflow guards.
    pub dropped: u64,
    /// Exact cluster-wide SLO-violation count.
    pub violations: u64,
    /// Measurement window in seconds (for rate metrics).
    pub measure_secs: f64,
}

impl FleetRun {
    /// Per-machine p99 latencies (µs), machine-index order. Machines the
    /// router never picked report 0.
    pub fn p99s_us(&self) -> Vec<f64> {
        self.machines.iter().map(|m| m.tail.p99_us).collect()
    }

    /// Cross-machine summary statistics of the per-machine p99 — the
    /// fleet restatement of the paper's variability claim.
    pub fn p99_summary(&self) -> Summary {
        Summary::from_iter(self.p99s_us())
    }

    /// Max − min of the per-machine p99 (µs): the straggler gap.
    pub fn p99_spread_us(&self) -> f64 {
        let s = self.p99_summary();
        if s.count() == 0 { 0.0 } else { s.max() - s.min() }
    }

    /// Synthesize a cluster-level [`WebRun`] so fleet cells slot into
    /// the same tables as single-machine cells: tails come from the
    /// *merged* recorders, counters sum, and machine-quality metrics
    /// (GHz, IPC, shares) average over machines.
    pub fn cluster_run(&self) -> WebRun {
        let n = self.machines.len().max(1) as f64;
        let secs = self.measure_secs.max(1e-9);
        let mean = |f: &dyn Fn(&WebRun) -> f64| self.machines.iter().map(f).sum::<f64>() / n;
        let sum = |f: &dyn Fn(&WebRun) -> f64| self.machines.iter().map(f).sum::<f64>();
        let mut license_share = [0.0f64; 3];
        for m in &self.machines {
            for (acc, v) in license_share.iter_mut().zip(m.license_share) {
                *acc += v / n;
            }
        }
        let insns: f64 = self
            .machines
            .iter()
            .map(|m| m.insns_per_req * m.completed as f64)
            .sum();
        WebRun {
            cfg_name: format!(
                "fleet({})/{}/{}",
                self.machines.len(),
                self.router,
                self.machines.first().map(|m| m.cfg_name.as_str()).unwrap_or("?")
            ),
            throughput_rps: self.completed as f64 / secs,
            avg_ghz: mean(&|m| m.avg_ghz),
            ipc: mean(&|m| m.ipc),
            insns_per_req: if self.completed > 0 { insns / self.completed as f64 } else { 0.0 },
            tail: self.tail,
            tenant_tails: self
                .tenant_stats
                .iter()
                .map(|(name, s)| (name.clone(), s.summary()))
                .collect(),
            stats: self.stats.clone(),
            tenant_stats: self.tenant_stats.iter().map(|(_, s)| s.clone()).collect(),
            dropped: self.dropped,
            type_changes_per_sec: sum(&|m| m.type_changes_per_sec),
            migrations_per_sec: sum(&|m| m.migrations_per_sec),
            cross_socket_migrations_per_sec: sum(&|m| m.cross_socket_migrations_per_sec),
            runtime_steered: self.machines.iter().map(|m| m.runtime_steered).sum(),
            runtime_migrations: self.machines.iter().map(|m| m.runtime_migrations).sum(),
            runtime_migrations_per_sec: sum(&|m| m.runtime_migrations_per_sec),
            runtime_preemptions: self.machines.iter().map(|m| m.runtime_preemptions).sum(),
            // Joules add across machines (same law as the recorders).
            active_energy_j: sum(&|m| m.active_energy_j),
            idle_energy_j: sum(&|m| m.idle_energy_j),
            throttle_ratio: mean(&|m| m.throttle_ratio),
            license_share,
            completed: self.completed,
            final_avx_cores: self.machines.iter().map(|m| m.final_avx_cores).sum(),
            adaptive_changes: self.machines.iter().map(|m| m.adaptive_changes).sum(),
            // Per-domain clocks are a machine-local concept; fleet rows
            // keep the aggregate avg_ghz instead.
            domain_ghz: Vec::new(),
        }
    }
}

/// Demultiplex the fleet arrival stream into per-machine traces.
/// Exposed for tests; [`run_fleet`] is the normal entry point.
pub fn route_stream(cfg: &FleetCfg) -> Vec<Vec<(Time, u32)>> {
    let process = cfg
        .cfg
        .mode
        .process()
        .expect("validate() rejects closed-loop fleets");
    let mut gen = ArrivalGen::new(process.clone(), cfg.cfg.seed ^ 0xDEAD);
    let mut router: Router = cfg.router.build(cfg.machines);
    let horizon = cfg.cfg.warmup + cfg.cfg.measure;
    let mut traces: Vec<Vec<(Time, u32)>> = vec![Vec::new(); cfg.machines.max(1)];
    let mut now = 0;
    loop {
        let (t, tenant) = gen.next_after(now);
        if t > horizon {
            break;
        }
        let avx = process.tenant_carries_avx(tenant as usize);
        traces[router.route(t, avx)].push((t, tenant));
        now = t;
    }
    traces
}

/// Run the fleet: demultiplex, simulate every machine across up to
/// `threads` OS threads (byte-identical at any thread count — machines
/// are seeded and traced independently of scheduling and collected by
/// index), and merge the per-machine recorders into cluster aggregates.
pub fn run_fleet(cfg: &FleetCfg, threads: usize) -> FleetRun {
    cfg.validate().expect("invalid fleet configuration");
    let traces = route_stream(cfg);
    let arrivals_routed: Vec<u64> = traces.iter().map(|t| t.len() as u64).collect();

    // Each trace is consumed exactly once, so hand ownership to the
    // claiming worker through a take-once slot instead of cloning what
    // can be millions of arrival entries per machine.
    let jobs: Vec<(WebCfg, Mutex<Option<Vec<(Time, u32)>>>)> = traces
        .into_iter()
        .enumerate()
        .map(|(i, trace)| {
            let mut mcfg = cfg.cfg.clone();
            mcfg.seed = cfg.machine_seed(i);
            (mcfg, Mutex::new(Some(trace)))
        })
        .collect();

    let n_threads = threads.max(1).min(jobs.len());
    let cursor = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<WebRun>>> = jobs.iter().map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..n_threads {
            scope.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= jobs.len() {
                    break;
                }
                let (mcfg, trace_slot) = &jobs[i];
                let trace = trace_slot
                    .lock()
                    .expect("trace poisoned")
                    .take()
                    .expect("each machine's trace is claimed exactly once");
                let run = run_webserver_trace(mcfg, trace);
                *slots[i].lock().expect("slot poisoned") = Some(run);
            });
        }
    });
    let machines: Vec<WebRun> = slots
        .into_iter()
        .map(|s| {
            s.into_inner()
                .expect("slot poisoned")
                .expect("every machine claimed and executed")
        })
        .collect();

    // Cluster-wide aggregation: merge recorders, sum exact counters.
    let mut stats = LatencyStats::new(cfg.cfg.slo);
    let names: Vec<String> = machines
        .first()
        .map(|m| m.tenant_tails.iter().map(|(n, _)| n.clone()).collect())
        .unwrap_or_default();
    let mut tenant_stats: Vec<(String, LatencyStats)> = names
        .into_iter()
        .map(|n| (n, LatencyStats::new(cfg.cfg.slo)))
        .collect();
    let mut dropped = 0;
    for m in &machines {
        stats.merge(&m.stats);
        for ((_, acc), ts) in tenant_stats.iter_mut().zip(&m.tenant_stats) {
            acc.merge(ts);
        }
        dropped += m.dropped;
    }
    FleetRun {
        router: cfg.router.label(),
        arrivals_routed,
        tail: stats.summary(),
        completed: stats.completed(),
        violations: stats.violations(),
        stats,
        tenant_stats,
        machines,
        dropped,
        measure_secs: cfg.cfg.measure as f64 / SEC as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::PolicyKind;
    use crate::sim::MS;
    use crate::traffic::ArrivalProcess;
    use crate::workload::client::LoadMode;
    use crate::workload::crypto::Isa;

    fn tiny_cfg() -> WebCfg {
        let mut c = WebCfg::paper_default(Isa::Avx512, PolicyKind::Unmodified);
        c.cores = 2;
        c.workers = 4;
        c.page_bytes = 8 * 1024;
        c.warmup = 50 * MS;
        c.measure = 150 * MS;
        c.mode = LoadMode::OpenProcess {
            process: ArrivalProcess::two_tenant(30_000.0, 0.25),
        };
        c
    }

    #[test]
    fn route_stream_partitions_by_tenant() {
        let fleet = FleetCfg::new(4, RouterSpec::AvxPartition { avx_machines: 1 }, tiny_cfg());
        let traces = route_stream(&fleet);
        assert_eq!(traces.len(), 4);
        // The AVX tenant (index 1) lands only on the last machine.
        for t in &traces[..3] {
            assert!(t.iter().all(|&(_, tenant)| tenant == 0), "avx on a scalar machine");
        }
        assert!(traces[3].iter().all(|&(_, tenant)| tenant == 1));
        assert!(!traces[3].is_empty(), "avx subset must receive work");
        // Each trace is strictly increasing in time.
        for t in &traces {
            assert!(t.windows(2).all(|w| w[0].0 < w[1].0));
        }
    }

    #[test]
    fn round_robin_splits_evenly() {
        let fleet = FleetCfg::new(3, RouterSpec::RoundRobin, tiny_cfg());
        let traces = route_stream(&fleet);
        let lens: Vec<usize> = traces.iter().map(|t| t.len()).collect();
        let (min, max) = (lens.iter().min().unwrap(), lens.iter().max().unwrap());
        assert!(max - min <= 1, "round robin must split evenly: {lens:?}");
    }

    #[test]
    fn machine_zero_keeps_the_fleet_seed() {
        let fleet = FleetCfg::new(3, RouterSpec::RoundRobin, tiny_cfg());
        assert_eq!(fleet.machine_seed(0), fleet.cfg.seed);
        assert_ne!(fleet.machine_seed(1), fleet.machine_seed(2));
        assert_ne!(fleet.machine_seed(1), fleet.cfg.seed);
    }

    #[test]
    fn fleet_aggregates_sum_machine_counters() {
        let fleet = FleetCfg::new(2, RouterSpec::RoundRobin, tiny_cfg());
        let run = run_fleet(&fleet, 2);
        assert_eq!(run.machines.len(), 2);
        let sum: u64 = run.machines.iter().map(|m| m.completed).sum();
        assert_eq!(run.completed, sum);
        assert_eq!(run.tail.completed, sum);
        let viol: u64 = run.machines.iter().map(|m| m.stats.violations()).sum();
        assert_eq!(run.violations, viol);
        assert!(run.completed > 100, "fleet served {}", run.completed);
        let cluster = run.cluster_run();
        assert_eq!(cluster.completed, sum);
        assert_eq!(cluster.tail.p99_us, run.tail.p99_us);
    }
}
