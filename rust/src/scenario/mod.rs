//! Declarative scenario matrices: sweep topology × policy × workload ×
//! ISA (the AVX-ratio axis) × load level × arrival process × fleet size
//! × router × DVFS governor in one parallel, deterministic run.
//!
//! The paper evaluates one configuration at a time on one machine; the
//! ROADMAP's production north-star needs *families* of configurations —
//! multi-socket NUMA topologies, every policy, several workloads —
//! compared under identical load. A [`ScenarioMatrix`] declares the axes,
//! [`ScenarioMatrix::cells`] expands the cartesian product into
//! self-contained [`Scenario`]s with per-cell seeds derived from the base
//! seed and the cell index, and [`ScenarioMatrix::run`] executes the
//! cells across OS threads (each cell's simulator is single-threaded and
//! self-contained, so cells parallelize perfectly) and funnels the
//! results into one [`crate::metrics::matrix_report`] comparison table.
//!
//! Determinism: a cell's outcome depends only on its own [`WebCfg`],
//! whose seed is a pure function of `(base_seed, warmup group)` — never
//! of thread scheduling — and results are collected by cell index.
//! Running the same matrix with 1 thread or 16 produces a byte-identical
//! table (property-tested in `rust/tests/scenario_matrix.rs`).
//!
//! Incremental sweeps: a `measures` axis makes consecutive cells differ
//! only in their measurement window, and [`ScenarioMatrix::run`] then
//! simulates each group's shared warmup prefix once and checkpoint-forks
//! it per cell ([`WebSim::fork`]) instead of cold-starting every cell —
//! byte-identical to the cold path (differential-tested in
//! `rust/tests/incremental.rs`), with the skipped simulated warmup
//! reported in [`MatrixResult::warmup_ns_reused`].
//!
//! # Examples
//!
//! Declare a 2 × 2 matrix (two topologies × two policies) and inspect
//! its expansion without running it:
//!
//! ```
//! use avxfreq::scenario::{PolicySpec, ScenarioMatrix, TopologySpec, WorkloadSpec};
//! use avxfreq::workload::crypto::Isa;
//!
//! let mut m = ScenarioMatrix::new(0x5EED);
//! m.topologies = vec![TopologySpec::single_socket_paper(), TopologySpec::dual_socket_paper()];
//! m.policies = vec![PolicySpec::Unmodified, PolicySpec::CoreSpecNuma { avx_cores_per_socket: 2 }];
//! m.workloads = vec![WorkloadSpec::compressed_page()];
//! m.isas = vec![Isa::Avx512];
//!
//! let cells = m.cells();
//! assert_eq!(cells.len(), 4);
//! assert_eq!(cells[0].topology, "1x12");
//! assert_eq!(cells[3].topology, "2x12");
//! assert_eq!(cells[3].cfg.sockets, 2);
//! // Per-cell seeds are distinct but fully determined by the base seed.
//! assert_ne!(cells[0].seed, cells[1].seed);
//! assert_eq!(m.cells()[1].seed, cells[1].seed);
//! ```

use crate::cpu::{GovernorSpec, HybridSpec, Topology};
use crate::faults::FaultsCfg;
use crate::fleet::{
    run_fleet, run_hier_fleet, BalancerCfg, FleetCfg, FleetRun, HierFleetCfg, HierFleetRun,
    RouterSpec,
};
use crate::sched::PolicyKind;
use crate::sim::{Time, MS, SEC};
use crate::tpc::{PlacementSpec, TpcParams};
use crate::traffic::{ArrivalProcess, RecorderArena};
use crate::util::mix64;
use crate::util::table::Table;
use crate::workload::client::{LoadMode, DEFAULT_SLO};
use crate::workload::crypto::Isa;
use crate::workload::webserver::{run_webserver, WebCfg, WebRun, WebSim};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

/// One point on the topology axis: a machine shape.
#[derive(Clone, Debug)]
pub struct TopologySpec {
    /// Short label used in tables (e.g. `2x12`).
    pub name: String,
    /// Server cores, split over `sockets` contiguous balanced chunks.
    pub cores: usize,
    /// Sockets (NUMA nodes / frequency domains).
    pub sockets: usize,
    /// P/E-core split (`None` = homogeneous, the classic shape — every
    /// pre-hybrid builder leaves this unset so default matrices expand
    /// byte-identically). When set, `cores` must equal the spec's total.
    pub hybrid: Option<HybridSpec>,
}

impl TopologySpec {
    /// The paper's evaluation machine: 12 server cores on one socket.
    pub fn single_socket_paper() -> Self {
        TopologySpec { name: "1x12".to_string(), cores: 12, sockets: 1, hybrid: None }
    }

    /// Two of the paper's machines in one chassis: 2 sockets × 12 server
    /// cores.
    pub fn dual_socket_paper() -> Self {
        TopologySpec { name: "2x12".to_string(), cores: 24, sockets: 2, hybrid: None }
    }

    /// Arbitrary `sockets` × `cores_per_socket` shape.
    pub fn multi(sockets: usize, cores_per_socket: usize) -> Self {
        TopologySpec {
            name: format!("{sockets}x{cores_per_socket}"),
            cores: sockets * cores_per_socket,
            sockets,
            hybrid: None,
        }
    }

    /// The desktop hybrid part: 8 P-cores + 16 E-cores in 4-core
    /// modules, one socket (see [`HybridSpec::desktop_8p16e`]).
    pub fn hybrid_8p16e() -> Self {
        let h = HybridSpec::desktop_8p16e();
        TopologySpec {
            name: h.label(),
            cores: h.n_cores(),
            sockets: 1,
            hybrid: Some(h),
        }
    }

    /// The [`Topology`] this spec describes.
    pub fn topology(&self) -> Topology {
        let s = self.sockets.max(1);
        if self.cores % s == 0 {
            Topology::multi_socket(s, self.cores / s)
        } else {
            Topology::uniform(self.cores, s)
        }
    }
}

/// One point on the policy axis; instantiated against a topology (the
/// NUMA variant needs the socket count).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PolicySpec {
    /// Stock MuQSS.
    Unmodified,
    /// The paper's machine-global AVX-core set.
    CoreSpec { avx_cores: usize },
    /// Per-socket AVX-core sets ([`PolicyKind::CoreSpecNuma`]).
    CoreSpecNuma { avx_cores_per_socket: usize },
    /// §2.1 strict partitioning.
    StrictPartition { avx_cores: usize },
    /// Hybrid-native specialization: the hardware P/E partition *is* the
    /// AVX-core set ([`PolicyKind::ClassNative`]).
    ClassNative { p_cores: usize },
}

impl PolicySpec {
    /// Table label, including the AVX-core parameter.
    pub fn label(&self) -> String {
        match self {
            PolicySpec::Unmodified => "unmodified".to_string(),
            PolicySpec::CoreSpec { avx_cores } => format!("core-spec({avx_cores})"),
            PolicySpec::CoreSpecNuma { avx_cores_per_socket } => {
                format!("core-spec-numa({avx_cores_per_socket}/skt)")
            }
            PolicySpec::StrictPartition { avx_cores } => format!("strict({avx_cores})"),
            PolicySpec::ClassNative { p_cores } => format!("class-native({p_cores})"),
        }
    }

    /// Concrete [`PolicyKind`] for a machine of the given shape.
    pub fn instantiate(&self, topo: &TopologySpec) -> PolicyKind {
        match *self {
            PolicySpec::Unmodified => PolicyKind::Unmodified,
            PolicySpec::CoreSpec { avx_cores } => PolicyKind::CoreSpec { avx_cores },
            PolicySpec::CoreSpecNuma { avx_cores_per_socket } => PolicyKind::CoreSpecNuma {
                avx_cores_per_socket,
                sockets: topo.sockets.max(1),
            },
            PolicySpec::StrictPartition { avx_cores } => {
                PolicyKind::StrictPartition { avx_cores }
            }
            PolicySpec::ClassNative { p_cores } => PolicyKind::ClassNative { p_cores },
        }
    }
}

/// One point on the workload axis.
#[derive(Clone, Debug)]
pub struct WorkloadSpec {
    /// Short label used in tables.
    pub name: String,
    /// Compress the page on the fly (the paper's main scenario).
    pub compress: bool,
    /// Page size in KiB.
    pub page_kib: usize,
    /// Offered open-loop load per server core (req/s); multiplied by the
    /// topology's core count so every machine shape sees equal pressure
    /// per core.
    pub rate_per_core: f64,
}

impl WorkloadSpec {
    /// The paper's compressed-page scenario (72 KiB, 5 000 req/s/core —
    /// the paper's 60 000 req/s over its 12 cores).
    pub fn compressed_page() -> Self {
        WorkloadSpec {
            name: "compressed".to_string(),
            compress: true,
            page_kib: 72,
            rate_per_core: 5_000.0,
        }
    }

    /// The uncompressed variant (crypto-dominated requests).
    pub fn plain_page() -> Self {
        WorkloadSpec {
            name: "plain".to_string(),
            compress: false,
            page_kib: 72,
            rate_per_core: 33_000.0,
        }
    }
}

/// One point on the arrival-process axis; instantiated against the
/// cell's total offered rate (so a spec stays meaningful across
/// topologies and load levels).
#[derive(Clone, Debug, PartialEq)]
pub enum ArrivalSpec {
    /// Homogeneous Poisson (wrk2's model).
    Poisson,
    /// Mean-preserving on/off bursts: `burst_factor ×` the mean rate for
    /// a `duty` fraction of each `period` (see
    /// [`ArrivalProcess::bursty_mean`]).
    Bursty { burst_factor: f64, duty: f64, period: Time },
    /// Sinusoidal ramp (compressed diurnal pattern).
    Diurnal { swing: f64, period: Time },
    /// Two-tenant mix: an AVX tenant carrying `avx_share` of the
    /// traffic, a scalar (SSE4, unannotated) tenant with the rest.
    TenantMix { avx_share: f64 },
    /// The bursty multi-tenant mix: both tenants of a
    /// [`ArrivalSpec::TenantMix`] burst *in phase* (a flash crowd with a
    /// fixed AVX/scalar composition; see
    /// [`ArrivalProcess::bursty_two_tenant`]).
    BurstyMix { avx_share: f64, burst_factor: f64, duty: f64, period: Time },
}

impl ArrivalSpec {
    /// Default burst shape: 2× bursts, 30% duty, 200 ms period.
    pub fn bursty_default() -> Self {
        ArrivalSpec::Bursty { burst_factor: 2.0, duty: 0.3, period: 200 * MS }
    }

    /// Default diurnal shape: ±60% swing over a 400 ms (compressed) day.
    pub fn diurnal_default() -> Self {
        ArrivalSpec::Diurnal { swing: 0.6, period: 400 * MS }
    }

    /// Default bursty multi-tenant mix: 30% AVX share, both tenants
    /// bursting in phase at 1.5× for 30% of a 90 ms period (the fleet
    /// layer's flash-crowd scenario).
    pub fn bursty_mix_default() -> Self {
        ArrivalSpec::BurstyMix { avx_share: 0.3, burst_factor: 1.5, duty: 0.3, period: 90 * MS }
    }

    /// Table label.
    pub fn label(&self) -> String {
        match self {
            ArrivalSpec::Poisson => "poisson".to_string(),
            ArrivalSpec::Bursty { .. } => "bursty".to_string(),
            ArrivalSpec::Diurnal { .. } => "diurnal".to_string(),
            ArrivalSpec::TenantMix { .. } => "mix".to_string(),
            ArrivalSpec::BurstyMix { .. } => "bursty-mix".to_string(),
        }
    }

    /// Concrete process offering `rate` requests/second on average.
    pub fn instantiate(&self, rate: f64) -> ArrivalProcess {
        match *self {
            ArrivalSpec::Poisson => ArrivalProcess::Poisson { rate },
            ArrivalSpec::Bursty { burst_factor, duty, period } => {
                ArrivalProcess::bursty_mean(rate, burst_factor, duty, period)
            }
            ArrivalSpec::Diurnal { swing, period } => {
                ArrivalProcess::Diurnal { mean_rate: rate, swing, period }
            }
            ArrivalSpec::TenantMix { avx_share } => {
                ArrivalProcess::two_tenant(rate, avx_share)
            }
            ArrivalSpec::BurstyMix { avx_share, burst_factor, duty, period } => {
                ArrivalProcess::bursty_two_tenant(rate, avx_share, burst_factor, duty, period)
            }
        }
    }
}

/// One point on the executor axis: how a cell's requests reach its
/// worker tasks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecutorSpec {
    /// The classic shared-queue server: mitigation (if any) lives in the
    /// kernel scheduler ([`PolicyKind`]). The default — a matrix that
    /// never touches the axis expands exactly as before.
    Kernel,
    /// Thread-per-core executor ([`crate::tpc`]): one worker per server
    /// core, per-core queues, and the runtime's own AVX-aware placement.
    Tpc { placement: PlacementSpec },
}

impl ExecutorSpec {
    /// Table/label suffix (empty for the kernel default).
    pub fn label(&self) -> String {
        match self {
            ExecutorSpec::Kernel => String::new(),
            ExecutorSpec::Tpc { placement } => format!("tpc:{}", placement.label()),
        }
    }
}

/// One point on the fault axis: which deterministic fault schedule (if
/// any) the cell's fleet runs under. Instantiated against the cell's
/// measurement window and fleet size ([`FaultsCfg::chaos`]), the same
/// late-binding pattern as [`ArrivalSpec`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultSpec {
    /// No faults — the cell expands and runs exactly as before this
    /// axis existed (the differential anchor).
    None,
    /// The chaos preset: one crash, one degradation window, one
    /// network-fault window, one skewed clock (see [`FaultsCfg::chaos`]).
    Chaos,
}

impl FaultSpec {
    /// Label suffix (empty for the fault-free default).
    pub fn label(&self) -> &'static str {
        match self {
            FaultSpec::None => "",
            FaultSpec::Chaos => "chaos",
        }
    }
}

/// A fully expanded cell of the matrix: labels, a derived seed, and the
/// self-contained web-server configuration to simulate.
#[derive(Clone, Debug)]
pub struct Scenario {
    /// Position in the expansion order (stable across runs).
    pub index: usize,
    pub topology: String,
    pub sockets: usize,
    pub policy: String,
    pub workload: String,
    pub isa: Isa,
    /// Load-level multiplier applied to the workload's per-core rate.
    pub load: f64,
    /// Arrival-process label (see [`ArrivalSpec::label`]).
    pub arrival: String,
    /// Fleet size: number of machines behind the front-end (1 = the
    /// classic single-machine cell, run without the fleet layer).
    pub fleet: usize,
    /// Router demultiplexing the cell's arrival stream over the fleet.
    pub router: RouterSpec,
    /// DVFS governor every machine of the cell runs under.
    pub governor: GovernorSpec,
    /// How requests reach workers: shared-queue kernel scheduling or the
    /// thread-per-core executor.
    pub executor: ExecutorSpec,
    /// Closed-loop front-end balancer (disabled = the classic open-loop
    /// front-end; enabled cells run the hierarchical fleet layer).
    pub balancer: BalancerCfg,
    /// Deterministic fault schedule the cell's fleet runs under
    /// (`FaultSpec::None` = fault-free, the classic cell; faulted cells
    /// run the hierarchical layer at any fleet size, since that is
    /// where the fault timeline lives).
    pub faults: FaultSpec,
    /// Measurement window drawn from the matrix's `measures` axis, or
    /// `None` when that axis is unset (the cell then measures the
    /// matrix-wide `measure` and labels exactly as before). Cells that
    /// differ only in this value share their entire warmup prefix —
    /// the divergence point the incremental runner forks at.
    pub measure_point: Option<Time>,
    /// Per-cell seed: a pure function of the base seed and the cell's
    /// *warmup group* (cells differing only in `measure_point` share
    /// it — their prefixes must be identical to be forkable; without a
    /// `measures` axis this is the classic per-index seed).
    pub seed: u64,
    pub cfg: WebCfg,
}

impl Scenario {
    /// Whether this cell runs through the fleet layer ([`run_fleet`])
    /// rather than the classic single-machine simulator. The single
    /// source of truth for both [`ScenarioMatrix::run`]'s dispatch and
    /// the [`Scenario::label`] suffix, so cells on different code paths
    /// can never share a label.
    pub fn uses_fleet_layer(&self) -> bool {
        self.fleet > 1 || self.router != RouterSpec::RoundRobin
    }

    /// Whether this cell runs through the hierarchical closed-loop
    /// layer ([`run_hier_fleet`]) — checked before
    /// [`Scenario::uses_fleet_layer`] in the dispatch, since a
    /// feedback-enabled cell needs the epoch loop at any fleet size.
    pub fn uses_hier_layer(&self) -> bool {
        self.balancer.enabled || self.faults != FaultSpec::None
    }

    /// One-line identifier for notes and logs.
    pub fn label(&self) -> String {
        let mut s = format!(
            "{}/{}/{}/{}/{}@{:.2}",
            self.topology,
            self.isa.name(),
            self.policy,
            self.workload,
            self.arrival,
            self.load,
        );
        if self.uses_fleet_layer() {
            s.push_str(&format!("/x{}/{}", self.fleet, self.router.label()));
        }
        if self.governor != GovernorSpec::IntelLegacy {
            s.push_str(&format!("/{}", self.governor.name()));
        }
        if self.executor != ExecutorSpec::Kernel {
            s.push_str(&format!("/{}", self.executor.label()));
        }
        if self.balancer.enabled {
            s.push_str(&format!("/{}", self.balancer.label()));
        }
        if self.faults != FaultSpec::None {
            s.push_str(&format!("/{}", self.faults.label()));
        }
        if let Some(w) = self.measure_point {
            s.push_str(&format!("/win{}ms", w / MS));
        }
        s
    }
}

/// Result of one executed cell. Fleet cells (`scenario.fleet > 1` or a
/// non-default router) carry the full [`FleetRun`]; `run` is then the
/// synthesized cluster-level [`WebRun`] so every report renders
/// uniformly.
#[derive(Clone, Debug)]
pub struct CellResult {
    pub scenario: Scenario,
    pub run: WebRun,
    pub fleet: Option<FleetRun>,
    /// Hierarchical-fleet result for feedback-enabled cells
    /// (`scenario.balancer.enabled`); `run` is then the synthesized
    /// cluster-level [`WebRun`].
    pub hier: Option<HierFleetRun>,
}

/// All cells of an executed matrix, in expansion order.
#[derive(Clone, Debug)]
pub struct MatrixResult {
    pub cells: Vec<CellResult>,
    /// Simulated warmup nanoseconds *not* re-simulated because a cell
    /// was forked from a warmed checkpoint instead of cold-started
    /// (`cfg.warmup` per forked cell). A deterministic work-avoidance
    /// measure — a pure function of the matrix declaration, independent
    /// of wall clock and thread count — recorded in the bench
    /// fingerprint. 0 when `incremental` is off or no cells share a
    /// warmup prefix.
    pub warmup_ns_reused: u64,
}

impl MatrixResult {
    /// The unified comparison table (see [`crate::metrics::matrix_report`]).
    pub fn table(&self) -> Table {
        crate::metrics::matrix_report(&self.cells)
    }

    /// The per-cell / per-tenant tail-latency table (see
    /// [`crate::metrics::tail_report`]).
    pub fn tail_table(&self) -> Table {
        crate::metrics::tail_report(&self.cells)
    }

    /// Per-machine + cluster rows for every fleet cell (see
    /// [`crate::metrics::fleet_report`]); empty-bodied table when the
    /// matrix has no fleet cells.
    pub fn fleet_table(&self) -> Table {
        let labeled: Vec<(String, &FleetRun)> = self
            .cells
            .iter()
            .filter_map(|c| c.fleet.as_ref().map(|f| (c.scenario.index.to_string(), f)))
            .collect();
        let pairs: Vec<(&str, &FleetRun)> =
            labeled.iter().map(|(s, f)| (s.as_str(), *f)).collect();
        crate::metrics::fleet_report(&pairs)
    }

    /// Render the fleet table as aligned text.
    pub fn render_fleet(&self) -> String {
        self.fleet_table().render()
    }

    /// Per-rack + cluster rows for every closed-loop cell (see
    /// [`crate::metrics::hier_report`]); empty-bodied table when the
    /// matrix has no feedback-enabled cells.
    pub fn hier_table(&self) -> Table {
        let labeled: Vec<(String, &HierFleetRun)> = self
            .cells
            .iter()
            .filter_map(|c| c.hier.as_ref().map(|h| (c.scenario.index.to_string(), h)))
            .collect();
        let pairs: Vec<(&str, &HierFleetRun)> =
            labeled.iter().map(|(s, h)| (s.as_str(), *h)).collect();
        crate::metrics::hier_report(&pairs)
    }

    /// Render the hierarchical-fleet table as aligned text.
    pub fn render_hier(&self) -> String {
        self.hier_table().render()
    }

    /// Render the comparison table as aligned text.
    pub fn render(&self) -> String {
        self.table().render()
    }

    /// Render the tail-latency table as aligned text.
    pub fn render_tail(&self) -> String {
        self.tail_table().render()
    }

    /// Write the table to `results/matrix.csv`.
    pub fn save_csv(&self) -> anyhow::Result<std::path::PathBuf> {
        self.table().save_csv("matrix")
    }

    /// Look up a cell's throughput by labels (for repro runners).
    pub fn throughput(&self, topology: &str, isa: Isa, policy: &str) -> Option<f64> {
        self.cells
            .iter()
            .find(|c| {
                c.scenario.topology == topology
                    && c.scenario.isa == isa
                    && c.scenario.policy == policy
            })
            .map(|c| c.run.throughput_rps)
    }

    /// Look up one cell by the full label set, including the traffic
    /// axes. `load` values come from the matrix declaration, so exact
    /// float comparison is the right equality here.
    pub fn find_cell(
        &self,
        topology: &str,
        isa: Isa,
        policy: &str,
        arrival: &str,
        load: f64,
    ) -> Option<&CellResult> {
        self.cells.iter().find(|c| {
            c.scenario.topology == topology
                && c.scenario.isa == isa
                && c.scenario.policy == policy
                && c.scenario.arrival == arrival
                && c.scenario.load == load
        })
    }
}

/// Declarative cartesian sweep over topology × policy × workload × ISA.
///
/// The ISA axis is the AVX-ratio axis: `sse4` requests execute no wide
/// instructions, `avx2` a moderate share, `avx512` the paper's heavy
/// share (see [`crate::workload::crypto::CryptoProfile`]).
#[derive(Clone, Debug)]
pub struct ScenarioMatrix {
    pub topologies: Vec<TopologySpec>,
    pub policies: Vec<PolicySpec>,
    pub workloads: Vec<WorkloadSpec>,
    pub isas: Vec<Isa>,
    /// Load-level multipliers on each workload's per-core rate
    /// (default `[1.0]`, so single-load sweeps look exactly as before).
    pub loads: Vec<f64>,
    /// Arrival processes to sweep (default `[Poisson]`).
    pub arrivals: Vec<ArrivalSpec>,
    /// Fleet sizes to sweep (default `[1]`: classic single-machine
    /// cells). A cell's offered rate scales with its fleet size so
    /// per-machine pressure stays comparable across the axis.
    pub fleet_sizes: Vec<usize>,
    /// Routers to sweep (default `[RoundRobin]`). Size-1 round-robin
    /// cells bypass the fleet layer entirely and run exactly as before.
    pub routers: Vec<RouterSpec>,
    /// DVFS governors to sweep (default `[IntelLegacy]`, which is
    /// bit-for-bit the pre-governor simulator — so default matrices are
    /// byte-identical to their pre-power-model output).
    pub governors: Vec<GovernorSpec>,
    /// Executors to sweep (default `[Kernel]`: the classic shared-queue
    /// server, leaving the expansion byte-identical to the pre-tpc
    /// matrix). `Tpc` cells run thread-per-core (`workers == cores`)
    /// with annotations forced on — the runtime needs the AVX marks the
    /// kernel's `unmodified` policy would otherwise drop.
    pub executors: Vec<ExecutorSpec>,
    /// Front-end balancers to sweep (default `[open-loop]`, which keeps
    /// the expansion byte-identical to the pre-balancer matrix).
    /// Feedback-enabled cells run through [`run_hier_fleet`]'s epoch
    /// loop at any fleet size.
    pub balancers: Vec<BalancerCfg>,
    /// Fault schedules to sweep (default `[FaultSpec::None]`, which
    /// keeps the expansion byte-identical to the pre-fault matrix).
    /// Faulted cells run through [`run_hier_fleet`] regardless of
    /// balancer, because the fault timeline lives in the hierarchical
    /// layer. Sits *outside* the measures axis so a warmup group still
    /// differs only in its window.
    pub faults: Vec<FaultSpec>,
    /// Measurement windows to sweep (default empty: every cell measures
    /// `self.measure` and the expansion is byte-identical to the
    /// pre-measures matrix). The *innermost* axis, and deliberately
    /// warmup-inert: consecutive cells differing only in their window
    /// share the entire warmup prefix, which is what makes them
    /// checkpoint-forkable (see [`ScenarioMatrix::run`]).
    pub measures: Vec<Time>,
    /// Latency SLO threshold applied to every cell.
    pub slo: Time,
    /// Hot-path optimizations for every cell's machines (bit-exact
    /// either way; the bench harness flips this for its baseline leg).
    pub fast_paths: bool,
    /// Fork consecutive same-prefix cells from one warmed checkpoint
    /// instead of re-simulating the warmup per cell (default on).
    /// Bit-exact either way — `rust/tests/incremental.rs` pins
    /// incremental-on ≡ incremental-off ≡ the cold single-cell runner —
    /// so this is purely a work-avoidance switch, like `fast_paths`.
    pub incremental: bool,
    /// Base seed; each cell derives `mix64(base_seed ^ f(index))`.
    pub base_seed: u64,
    /// Simulated warmup before measurement, per cell.
    pub warmup: Time,
    /// Simulated measurement window, per cell (unless the `measures`
    /// axis overrides it).
    pub measure: Time,
}

impl ScenarioMatrix {
    /// Empty matrix (fill the axes before calling [`ScenarioMatrix::run`]).
    pub fn new(base_seed: u64) -> Self {
        ScenarioMatrix {
            topologies: Vec::new(),
            policies: Vec::new(),
            workloads: Vec::new(),
            isas: Vec::new(),
            loads: vec![1.0],
            arrivals: vec![ArrivalSpec::Poisson],
            fleet_sizes: vec![1],
            routers: vec![RouterSpec::RoundRobin],
            governors: vec![GovernorSpec::IntelLegacy],
            executors: vec![ExecutorSpec::Kernel],
            balancers: vec![BalancerCfg::default()],
            faults: vec![FaultSpec::None],
            measures: Vec::new(),
            slo: DEFAULT_SLO,
            fast_paths: true,
            incremental: true,
            base_seed,
            warmup: 300 * MS,
            measure: SEC,
        }
    }

    /// The default 8-cell sweep behind `avxfreq matrix`: {single-socket,
    /// dual-socket NUMA} × {unmodified, per-socket core specialization}
    /// × {sse4, avx512} on the compressed-page workload.
    pub fn default_sweep(quick: bool, base_seed: u64) -> Self {
        let mut m = ScenarioMatrix::new(base_seed);
        m.topologies = vec![
            TopologySpec::single_socket_paper(),
            TopologySpec::dual_socket_paper(),
        ];
        m.policies = vec![
            PolicySpec::Unmodified,
            PolicySpec::CoreSpecNuma { avx_cores_per_socket: 2 },
        ];
        m.workloads = vec![WorkloadSpec::compressed_page()];
        m.isas = vec![Isa::Sse4, Isa::Avx512];
        if quick {
            m.warmup = 150 * MS;
            m.measure = 300 * MS;
        }
        m
    }

    /// The traffic-engine sweep behind `avxfreq traffic`: the paper's
    /// single-socket machine under {unmodified, core specialization} ×
    /// ≥3 load levels × ≥2 arrival processes, AVX-512 build, reporting
    /// the tail tables.
    pub fn traffic_sweep(quick: bool, base_seed: u64) -> Self {
        let mut m = ScenarioMatrix::new(base_seed);
        m.topologies = vec![TopologySpec::single_socket_paper()];
        m.policies = vec![PolicySpec::Unmodified, PolicySpec::CoreSpec { avx_cores: 2 }];
        m.workloads = vec![WorkloadSpec::compressed_page()];
        m.isas = vec![Isa::Avx512];
        m.loads = vec![0.6, 0.85, 1.1];
        m.arrivals = vec![ArrivalSpec::Poisson, ArrivalSpec::bursty_default()];
        if quick {
            m.warmup = 150 * MS;
            m.measure = 400 * MS;
        } else {
            m.warmup = 500 * MS;
            m.measure = 2 * SEC;
        }
        m
    }

    /// The governor sweep behind `avxfreq energy`: the paper's
    /// single-socket machine under {unmodified, core specialization} ×
    /// every DVFS governor, AVX-512 build, reporting the matrix table
    /// plus the per-cell energy table.
    pub fn energy_sweep(quick: bool, base_seed: u64) -> Self {
        let mut m = ScenarioMatrix::new(base_seed);
        m.topologies = vec![TopologySpec::single_socket_paper()];
        m.policies = vec![PolicySpec::Unmodified, PolicySpec::CoreSpec { avx_cores: 2 }];
        m.workloads = vec![WorkloadSpec::compressed_page()];
        m.isas = vec![Isa::Avx512];
        m.governors = GovernorSpec::all().to_vec();
        if quick {
            m.warmup = 150 * MS;
            m.measure = 300 * MS;
        } else {
            m.warmup = 500 * MS;
            m.measure = 2 * SEC;
        }
        m
    }

    /// The executor sweep behind `avxfreq tpc`: the paper's
    /// single-socket machine serving the uncompressed (crypto-dominated)
    /// AVX-512 workload through the thread-per-core executor under every
    /// placement policy, on the bursty multi-tenant mix — the scenario
    /// where runtime-level steering has room to move the tail. Kernel
    /// policy stays `unmodified`: the mitigation under test lives in the
    /// runtime.
    pub fn tpc_sweep(quick: bool, base_seed: u64) -> Self {
        let mut m = ScenarioMatrix::new(base_seed);
        m.topologies = vec![TopologySpec::single_socket_paper()];
        m.policies = vec![PolicySpec::Unmodified];
        m.workloads = vec![WorkloadSpec::plain_page()];
        m.isas = vec![Isa::Avx512];
        m.arrivals = vec![ArrivalSpec::bursty_mix_default()];
        m.executors = crate::tpc::all_placements(2)
            .iter()
            .map(|&placement| ExecutorSpec::Tpc { placement })
            .collect();
        if quick {
            m.warmup = 150 * MS;
            m.measure = 300 * MS;
        } else {
            m.warmup = 500 * MS;
            m.measure = 2 * SEC;
        }
        m
    }

    /// The incremental sweep behind `avxfreq incremental`: the default
    /// 8-cell sweep crossed with a short and a full measurement window
    /// (16 cells in 8 warmup groups of 2) — the window-sensitivity
    /// question a measurement-methodology study actually asks, and the
    /// shape where checkpoint forking pays: each group simulates its
    /// warmup once and forks, skipping exactly half the warmup work
    /// ([`MatrixResult::warmup_ns_reused`] reports the saving).
    pub fn incremental_sweep(quick: bool, base_seed: u64) -> Self {
        let mut m = ScenarioMatrix::default_sweep(quick, base_seed);
        m.measures = vec![m.measure / 2, m.measure];
        m
    }

    /// Number of cells the matrix expands to.
    pub fn len(&self) -> usize {
        self.topologies.len()
            * self.policies.len()
            * self.workloads.len()
            * self.isas.len()
            * self.loads.len()
            * self.arrivals.len()
            * self.fleet_sizes.len()
            * self.routers.len()
            * self.governors.len()
            * self.executors.len()
            * self.balancers.len()
            * self.faults.len()
            * self.measures.len().max(1)
    }

    /// Cells per warmup group: the run length of consecutive cells that
    /// differ only in their measurement window (1 without a `measures`
    /// axis — every cell is its own group and nothing is forked).
    pub fn warmup_group_size(&self) -> usize {
        self.measures.len().max(1)
    }

    /// True when any axis is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Expand the cartesian product, topology-major (load level, arrival
    /// process, fleet size, router, governor, executor, balancer, and
    /// measurement window are the innermost axes, in that order — with
    /// the default `[1] × [RoundRobin]` fleet axes, `[IntelLegacy]`
    /// governor axis, `[Kernel]` executor axis, `[open-loop]` balancer
    /// axis, and unset measures axis the expansion is exactly the
    /// pre-fleet cell order), into runnable cells.
    pub fn cells(&self) -> Vec<Scenario> {
        let mut out = Vec::with_capacity(self.len());
        // The measurement-window axis as expanded: `[None]` when unset,
        // so a measures-free matrix keeps its classic cell list.
        let measure_axis: Vec<Option<Time>> = if self.measures.is_empty() {
            vec![None]
        } else {
            self.measures.iter().map(|&w| Some(w)).collect()
        };
        let ma = &measure_axis;
        for topo in &self.topologies {
            for policy in &self.policies {
                for workload in &self.workloads {
                    for &isa in &self.isas {
                        for &load in &self.loads {
                            for arrival in &self.arrivals {
                                for &fleet in &self.fleet_sizes {
                                    for &router in &self.routers {
                                        for &governor in &self.governors {
                                            // Executor × balancer × faults ×
                                            // window: the innermost axes,
                                            // flattened to keep the nesting
                                            // depth sane (the window stays
                                            // innermost — warmup groups must
                                            // differ only in their window).
                                            for (&executor, &balancer, &faults, measure_point) in
                                                self.executors.iter().flat_map(|e| {
                                                    self.balancers.iter().flat_map(move |b| {
                                                        self.faults.iter().flat_map(move |f| {
                                                            ma.iter().map(move |&w| {
                                                                (e, b, f, w)
                                                            })
                                                        })
                                                    })
                                                })
                                            {
                                                let index = out.len();
                                                // Cells of one warmup group
                                                // (consecutive, differing only
                                                // in their window) share a
                                                // seed — identical prefixes
                                                // are what makes them
                                                // forkable. Without a measures
                                                // axis, group == index and
                                                // this is the classic formula.
                                                let group =
                                                    index / self.warmup_group_size();
                                                let seed = mix64(
                                                    self.base_seed
                                                        ^ (group as u64)
                                                            .wrapping_mul(0x9E37_79B9),
                                                );
                                                // Derive the machine shape through
                                                // the Topology model so the matrix
                                                // and the cpu layer agree on one
                                                // socket partition.
                                                let t = topo.topology();
                                                let mut cfg = WebCfg::paper_default(
                                                    isa,
                                                    policy.instantiate(topo),
                                                );
                                                cfg.cores = t.n_server_cores();
                                                cfg.sockets = t.n_sockets();
                                                // Homogeneous specs leave this
                                                // None — the machine then takes
                                                // the classic (byte-identical)
                                                // socket-domain path.
                                                cfg.hybrid = topo.hybrid;
                                                cfg.workers = t.n_server_cores() * 2;
                                                cfg.compress = workload.compress;
                                                cfg.page_bytes = workload.page_kib * 1024;
                                                // Fleet-total offered rate: equal
                                                // per-machine pressure across the
                                                // fleet-size axis.
                                                let rate = workload.rate_per_core
                                                    * topo.cores as f64
                                                    * load
                                                    * fleet.max(1) as f64;
                                                cfg.mode = match arrival {
                                                    // Poisson keeps the sugared form
                                                    // so a single-arrival matrix is
                                                    // exactly the pre-traffic
                                                    // configuration.
                                                    ArrivalSpec::Poisson => {
                                                        LoadMode::Open { rate }
                                                    }
                                                    spec => LoadMode::OpenProcess {
                                                        process: spec.instantiate(rate),
                                                    },
                                                };
                                                cfg.slo = self.slo;
                                                cfg.fast_paths = self.fast_paths;
                                                cfg.seed = seed;
                                                cfg.warmup = self.warmup;
                                                cfg.measure =
                                                    measure_point.unwrap_or(self.measure);
                                                cfg.governor = governor;
                                                if let ExecutorSpec::Tpc { placement } =
                                                    executor
                                                {
                                                    // Thread-per-core: worker i is
                                                    // executor core i. Annotations
                                                    // stay on regardless of kernel
                                                    // policy — the *runtime* needs
                                                    // the AVX marks.
                                                    cfg.workers = t.n_server_cores();
                                                    cfg.annotate = true;
                                                    cfg.mode = LoadMode::Executor {
                                                        process: arrival.instantiate(rate),
                                                        tpc: TpcParams {
                                                            placement,
                                                            ..TpcParams::default()
                                                        },
                                                    };
                                                }
                                                out.push(Scenario {
                                                    index,
                                                    topology: topo.name.clone(),
                                                    sockets: topo.sockets,
                                                    policy: policy.label(),
                                                    workload: workload.name.clone(),
                                                    isa,
                                                    load,
                                                    arrival: arrival.label(),
                                                    fleet: fleet.max(1),
                                                    router,
                                                    governor,
                                                    executor,
                                                    balancer,
                                                    faults,
                                                    measure_point,
                                                    seed,
                                                    cfg,
                                                });
                                            }
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        out
    }

    /// Execute every cell across `threads` OS threads and collect the
    /// results in cell order. The unit of work a thread claims (work
    /// stealing over an atomic cursor) is a *warmup group* — the run of
    /// [`ScenarioMatrix::warmup_group_size`] consecutive cells that
    /// differ only in their measurement window — so uneven durations
    /// cannot skew the result: outputs are keyed by cell index and each
    /// cell is seeded independently of scheduling, which keeps the
    /// rendered tables byte-identical at any thread count.
    ///
    /// Size-1 round-robin open-loop cells run the single-machine
    /// simulator directly (bit-identical to the pre-fleet matrix);
    /// feedback-enabled cells run [`run_hier_fleet`]'s epoch loop; any
    /// other fleet/router combination runs [`run_fleet`] — serially
    /// within the cell, since the cells themselves already saturate the
    /// thread pool — and reports the cluster-level [`WebRun`] plus the
    /// full [`FleetRun`] / [`HierFleetRun`].
    ///
    /// With `incremental` on, a warmup group of single-machine cells
    /// simulates its shared warmup prefix once ([`WebSim::run_warmup`]),
    /// checkpoint-forks the warmed state per cell ([`WebSim::fork`]) and
    /// runs only each cell's measurement window; per-cell latency
    /// recorders are recycled through a [`RecorderArena`]. The cold
    /// single-cell path above is the *reference* this must match
    /// byte-for-byte (differential-tested in
    /// `rust/tests/incremental.rs`); fleet and feedback cells always
    /// take it, as does any group whose task bodies decline to fork.
    pub fn run(&self, threads: usize) -> MatrixResult {
        let cells = self.cells();
        let gsize = self.warmup_group_size();
        debug_assert_eq!(cells.len() % gsize, 0, "expansion is a multiple of the group size");
        let n_groups = cells.len() / gsize;
        let n_threads = threads.max(1).min(n_groups.max(1));
        let cursor = AtomicUsize::new(0);
        let reused = AtomicU64::new(0);
        type CellOut = (WebRun, Option<FleetRun>, Option<HierFleetRun>);
        let slots: Vec<Mutex<Option<CellOut>>> =
            cells.iter().map(|_| Mutex::new(None)).collect();
        // The cold path: exactly the historical per-cell dispatch — the
        // byte-reference the forked path is tested against. Never "fix"
        // a forked/cold divergence by changing this side.
        let run_cold = |s: &Scenario| -> CellOut {
            if s.uses_hier_layer() {
                let fcfg = FleetCfg::new(s.fleet, s.router, s.cfg.clone());
                let mut hcfg = HierFleetCfg::new(fcfg, s.balancer);
                hcfg.machines_per_rack = s.fleet.max(1).min(8);
                if s.faults == FaultSpec::Chaos {
                    hcfg.faults = FaultsCfg::chaos(s.cfg.measure, s.fleet.max(1));
                }
                let h = run_hier_fleet(&hcfg, 1);
                (h.cluster_run(&s.workload), None, Some(h))
            } else if !s.uses_fleet_layer() {
                (run_webserver(&s.cfg), None, None)
            } else {
                let fcfg = FleetCfg::new(s.fleet, s.router, s.cfg.clone());
                let f = run_fleet(&fcfg, 1);
                (f.cluster_run(), Some(f), None)
            }
        };
        std::thread::scope(|scope| {
            for _ in 0..n_threads {
                scope.spawn(|| loop {
                    let g = cursor.fetch_add(1, Ordering::Relaxed);
                    if g >= n_groups {
                        break;
                    }
                    let group = &cells[g * gsize..(g + 1) * gsize];
                    // Forking applies to single-machine groups of ≥ 2
                    // cells; fleet/hier cells and singleton groups take
                    // the reference path (axes other than the window are
                    // constant within a group, so the first cell decides
                    // for all).
                    let forkable = self.incremental
                        && gsize > 1
                        && !group[0].uses_fleet_layer()
                        && !group[0].uses_hier_layer();
                    if !forkable {
                        for (j, s) in group.iter().enumerate() {
                            *slots[g * gsize + j].lock().expect("slot poisoned") =
                                Some(run_cold(s));
                        }
                        continue;
                    }
                    self.run_group_forked(group, &slots[g * gsize..(g + 1) * gsize], &reused);
                });
            }
        });
        let cells = cells
            .into_iter()
            .zip(slots)
            .map(|(scenario, slot)| {
                let (run, fleet, hier) = slot
                    .into_inner()
                    .expect("slot poisoned")
                    .expect("every cell claimed and executed");
                CellResult { scenario, run, fleet, hier }
            })
            .collect();
        MatrixResult { cells, warmup_ns_reused: reused.into_inner() }
    }

    /// Run one warmup group through the checkpoint-forking path: build
    /// the first cell's simulation, simulate the shared warmup prefix
    /// once, then fork each cell off the warmed checkpoint and run only
    /// its measurement window (the last cell consumes the checkpoint
    /// itself — its warmup was actually simulated, so it does not count
    /// as reused). Falls back to the cold reference path for the whole
    /// group if any task body declines to fork.
    fn run_group_forked(
        &self,
        group: &[Scenario],
        slots: &[Mutex<Option<(WebRun, Option<FleetRun>, Option<HierFleetRun>)>>],
        reused: &AtomicU64,
    ) {
        let mut arena = RecorderArena::new();
        let mut sim = Some(WebSim::new(&group[0].cfg));
        sim.as_mut().expect("just built").run_warmup();
        for (j, s) in group.iter().enumerate() {
            let run = if j + 1 == group.len() {
                let mut base = sim.take().expect("checkpoint consumed early");
                base.set_measure(s.cfg.measure);
                base.finish().0
            } else {
                match sim.as_ref().expect("checkpoint alive").fork(&mut arena) {
                    Some(mut f) => {
                        f.set_measure(s.cfg.measure);
                        reused.fetch_add(s.cfg.warmup, Ordering::Relaxed);
                        f.finish_into_arena(&mut arena)
                    }
                    // A body declined to fork: this cell falls back to
                    // the cold reference path (later cells decline
                    // identically; the final cell still consumes the
                    // warmed checkpoint, which *is* the reference
                    // build → warmup → finish sequence).
                    None => run_webserver(&s.cfg),
                }
            };
            *slots[j].lock().expect("slot poisoned") = Some((run, None, None));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn expansion_is_topology_major_and_seeded() {
        let m = ScenarioMatrix::default_sweep(true, 7);
        let cells = m.cells();
        assert_eq!(cells.len(), 8);
        assert_eq!(cells[0].topology, "1x12");
        assert_eq!(cells[4].topology, "2x12");
        assert_eq!(cells[4].cfg.sockets, 2);
        assert_eq!(cells[4].cfg.cores, 24);
        // Seeds distinct and reproducible.
        let again = m.cells();
        for (a, b) in cells.iter().zip(&again) {
            assert_eq!(a.seed, b.seed);
        }
        let mut seeds: Vec<u64> = cells.iter().map(|c| c.seed).collect();
        seeds.sort_unstable();
        seeds.dedup();
        assert_eq!(seeds.len(), 8, "per-cell seeds must be distinct");
    }

    #[test]
    fn rate_scales_with_core_count() {
        let m = ScenarioMatrix::default_sweep(true, 7);
        let cells = m.cells();
        let rate = |c: &Scenario| match &c.cfg.mode {
            LoadMode::Open { rate } => *rate,
            _ => panic!("open-loop expected"),
        };
        assert!((rate(&cells[0]) - 60_000.0).abs() < 1e-6);
        assert!((rate(&cells[4]) - 120_000.0).abs() < 1e-6);
    }

    #[test]
    fn traffic_axes_expand_innermost() {
        let mut m = ScenarioMatrix::default_sweep(true, 7);
        m.topologies.truncate(1);
        m.policies.truncate(1);
        m.isas.truncate(1);
        m.loads = vec![0.5, 1.0];
        m.arrivals = vec![ArrivalSpec::Poisson, ArrivalSpec::bursty_default()];
        let cells = m.cells();
        assert_eq!(cells.len(), 4);
        assert_eq!(cells[0].arrival, "poisson");
        assert_eq!(cells[1].arrival, "bursty");
        assert_eq!(cells[0].load, 0.5);
        assert_eq!(cells[2].load, 1.0);
        // The bursty cell's process preserves the scaled mean rate.
        match &cells[3].cfg.mode {
            LoadMode::OpenProcess { process } => {
                assert!((process.mean_rate() - 60_000.0).abs() < 1.0);
            }
            other => panic!("bursty cell must carry a process, got {other:?}"),
        }
        // Every cell inherits the matrix SLO.
        assert!(cells.iter().all(|c| c.cfg.slo == m.slo));
    }

    #[test]
    fn traffic_sweep_covers_required_grid() {
        let m = ScenarioMatrix::traffic_sweep(true, 9);
        assert!(m.loads.len() >= 3, "≥3 load levels required");
        assert!(m.arrivals.len() >= 2, "≥2 arrival processes required");
        let cells = m.cells();
        assert_eq!(cells.len(), m.len());
        assert!(cells.iter().any(|c| c.policy.contains("core-spec")));
        assert!(cells.iter().any(|c| c.arrival == "bursty"));
    }

    #[test]
    fn fleet_axes_expand_innermost_and_scale_rate() {
        let mut m = ScenarioMatrix::default_sweep(true, 7);
        m.topologies.truncate(1);
        m.policies.truncate(1);
        m.isas.truncate(1);
        m.fleet_sizes = vec![1, 4];
        m.routers = vec![RouterSpec::RoundRobin, RouterSpec::AvxPartition { avx_machines: 1 }];
        let cells = m.cells();
        assert_eq!(cells.len(), 4, "1 base cell × 2 fleet sizes × 2 routers");
        assert_eq!(cells[0].fleet, 1);
        assert_eq!(cells[1].router, RouterSpec::AvxPartition { avx_machines: 1 });
        assert_eq!(cells[2].fleet, 4);
        assert!(cells[3].label().contains("x4/avx-part(1)"));
        // Offered rate scales with fleet size: equal per-machine pressure.
        let rate = |c: &Scenario| match &c.cfg.mode {
            LoadMode::Open { rate } => *rate,
            _ => panic!("open-loop expected"),
        };
        assert!((rate(&cells[2]) - 4.0 * rate(&cells[0])).abs() < 1e-6);
        // Default axes leave the classic expansion untouched.
        let classic = ScenarioMatrix::default_sweep(true, 7);
        assert!(classic.cells().iter().all(|c| c.fleet == 1));
        assert_eq!(classic.cells().len(), 8);
    }

    #[test]
    fn governor_axis_expands_innermost_and_defaults_to_legacy() {
        // Default axes: every cell runs intel-legacy and the expansion
        // is exactly the pre-governor cell order (same count, same
        // seeds — the matrix-level differential anchor).
        let classic = ScenarioMatrix::default_sweep(true, 7);
        assert!(classic.cells().iter().all(|c| c.governor == GovernorSpec::IntelLegacy));
        assert_eq!(classic.cells().len(), 8);

        let mut m = ScenarioMatrix::default_sweep(true, 7);
        m.topologies.truncate(1);
        m.policies.truncate(1);
        m.isas.truncate(1);
        m.governors = GovernorSpec::all().to_vec();
        let cells = m.cells();
        assert_eq!(cells.len(), 3);
        assert_eq!(cells[0].governor, GovernorSpec::IntelLegacy);
        assert_eq!(cells[1].governor, GovernorSpec::SlowRamp);
        assert_eq!(cells[2].cfg.governor, GovernorSpec::DimSilicon);
        // Non-default governors show up in the cell label; the default
        // keeps the historical label.
        assert!(!cells[0].label().contains("intel-legacy"));
        assert!(cells[1].label().ends_with("/slow-ramp"));
        // The energy sweep covers both policies under every governor.
        let e = ScenarioMatrix::energy_sweep(true, 9);
        assert_eq!(e.len(), 6);
        assert!(e.cells().iter().any(|c| c.policy.contains("core-spec")
            && c.governor == GovernorSpec::DimSilicon));
    }

    #[test]
    fn executor_axis_expands_innermost_and_defaults_to_kernel() {
        // Default axes: every cell runs the shared-queue server and the
        // expansion is exactly the pre-tpc cell order (same count, same
        // seeds — the matrix-level differential anchor).
        let classic = ScenarioMatrix::default_sweep(true, 7);
        assert!(classic.cells().iter().all(|c| c.executor == ExecutorSpec::Kernel));
        assert_eq!(classic.cells().len(), 8);

        let mut m = ScenarioMatrix::default_sweep(true, 7);
        m.topologies.truncate(1);
        m.policies.truncate(1);
        m.isas.truncate(1);
        m.executors = vec![
            ExecutorSpec::Kernel,
            ExecutorSpec::Tpc { placement: PlacementSpec::AvxSteer { avx_cores: 2 } },
        ];
        let cells = m.cells();
        assert_eq!(cells.len(), 2);
        assert_eq!(cells[0].executor, ExecutorSpec::Kernel);
        assert!(matches!(cells[0].cfg.mode, LoadMode::Open { .. }));
        assert!(!cells[0].label().contains("tpc"));
        assert!(cells[1].label().ends_with("/tpc:avx-steer(2)"));
        // Tpc cells run thread-per-core with annotations forced on and
        // carry the arrival process inside LoadMode::Executor.
        assert_eq!(cells[1].cfg.workers, cells[1].cfg.cores);
        assert!(cells[1].cfg.annotate);
        match &cells[1].cfg.mode {
            LoadMode::Executor { process, tpc } => {
                assert!((process.mean_rate() - 60_000.0).abs() < 1.0);
                assert_eq!(
                    tpc.placement,
                    PlacementSpec::AvxSteer { avx_cores: 2 }
                );
                assert_eq!(tpc.quantum, u64::MAX, "matrix cells never preempt");
            }
            other => panic!("tpc cell must carry LoadMode::Executor, got {other:?}"),
        }
    }

    #[test]
    fn balancer_axis_expands_innermost_and_defaults_to_open_loop() {
        // Default axes: every cell is open-loop and the expansion is
        // exactly the pre-balancer cell order (same count, same seeds —
        // the matrix-level differential anchor).
        let classic = ScenarioMatrix::default_sweep(true, 7);
        assert!(classic.cells().iter().all(|c| !c.balancer.enabled));
        assert_eq!(classic.cells().len(), 8);

        let mut m = ScenarioMatrix::default_sweep(true, 7);
        m.topologies.truncate(1);
        m.policies.truncate(1);
        m.isas.truncate(1);
        m.balancers = vec![BalancerCfg::default(), BalancerCfg::closed()];
        let cells = m.cells();
        assert_eq!(cells.len(), 2);
        assert!(!cells[0].uses_hier_layer());
        assert!(!cells[0].label().contains("closed"));
        assert!(cells[1].uses_hier_layer());
        assert!(cells[1].label().ends_with("/closed(4ep)"));
        // A feedback-enabled cell routes through the hier layer even at
        // fleet size 1 / round-robin (uses_hier_layer is checked first
        // in the dispatch).
        assert_eq!(cells[1].fleet, 1);
        assert!(!cells[1].uses_fleet_layer());
    }

    #[test]
    fn fault_axis_expands_and_defaults_stay_classic() {
        // Default axes: every cell is fault-free and the expansion is
        // exactly the pre-fault cell order (same count, same seeds —
        // the matrix-level differential anchor).
        let classic = ScenarioMatrix::default_sweep(true, 7);
        assert!(classic.cells().iter().all(|c| c.faults == FaultSpec::None));
        assert_eq!(classic.cells().len(), 8);

        let mut m = ScenarioMatrix::default_sweep(true, 7);
        m.topologies.truncate(1);
        m.policies.truncate(1);
        m.isas.truncate(1);
        m.fleet_sizes = vec![2];
        m.faults = vec![FaultSpec::None, FaultSpec::Chaos];
        let cells = m.cells();
        assert_eq!(cells.len(), 2);
        assert_eq!(cells[0].faults, FaultSpec::None);
        assert!(!cells[0].label().contains("chaos"));
        assert!(!cells[0].uses_hier_layer());
        // A faulted cell routes through the hier layer even with the
        // open-loop balancer — the fault timeline lives there — and
        // says so in its label.
        assert_eq!(cells[1].faults, FaultSpec::Chaos);
        assert!(cells[1].uses_hier_layer());
        assert!(cells[1].label().ends_with("/chaos"), "label: {}", cells[1].label());
        // Both cells of the pair share every other axis: the fault axis
        // perturbs nothing upstream of the fleet layer.
        assert_eq!(cells[0].topology, cells[1].topology);
        assert_eq!(cells[0].fleet, cells[1].fleet);
        assert_eq!(cells[0].cfg.cores, cells[1].cfg.cores);
    }

    #[test]
    fn measures_axis_expands_innermost_and_defaults_stay_classic() {
        // Default: no measures axis — classic 8-cell expansion, every
        // cell its own warmup group, no window suffix in labels.
        let classic = ScenarioMatrix::default_sweep(true, 7);
        assert_eq!(classic.warmup_group_size(), 1);
        assert!(classic.incremental, "incremental is default-on");
        assert!(classic.cells().iter().all(|c| c.measure_point.is_none()));
        assert_eq!(classic.cells().len(), 8);

        let m = ScenarioMatrix::incremental_sweep(true, 7);
        assert_eq!(m.warmup_group_size(), 2);
        assert_eq!(m.len(), 16);
        let cells = m.cells();
        assert_eq!(cells.len(), 16);
        let base = classic.cells();
        for g in 0..8 {
            // The window is the innermost axis: groups are consecutive
            // pairs differing only in cfg.measure, sharing a seed (the
            // forkable-prefix invariant) — and the group seed is exactly
            // the underlying 8-cell sweep's per-index seed, so the axis
            // never perturbs the base expansion's streams.
            let (a, b) = (&cells[2 * g], &cells[2 * g + 1]);
            assert_eq!(a.seed, b.seed, "group {g} must share its seed");
            assert_eq!(a.seed, base[g].seed);
            assert_eq!(a.cfg.warmup, b.cfg.warmup);
            assert_eq!(a.cfg.measure * 2, b.cfg.measure, "short then full window");
            assert_eq!(a.topology, b.topology);
            assert_eq!(a.policy, b.policy);
            assert_eq!(a.isa, b.isa);
            assert_eq!(a.topology, base[g].topology);
            // Labels still distinguish the two cells of a group.
            assert_ne!(a.label(), b.label());
            assert!(a.label().ends_with("ms"), "window suffix expected: {}", a.label());
        }
    }

    #[test]
    fn tpc_sweep_covers_every_placement() {
        let m = ScenarioMatrix::tpc_sweep(true, 9);
        assert_eq!(m.len(), 3);
        let cells = m.cells();
        assert!(cells.iter().all(|c| c.policy == "unmodified"));
        assert!(cells.iter().all(|c| c.cfg.workers == c.cfg.cores));
        let labels: Vec<String> = cells.iter().map(|c| c.executor.label()).collect();
        assert_eq!(
            labels,
            vec!["tpc:home-core", "tpc:avx-steer(2)", "tpc:avx-steer-lazy(2)"]
        );
    }

    #[test]
    fn numa_policy_instantiates_with_topology_sockets() {
        let spec = PolicySpec::CoreSpecNuma { avx_cores_per_socket: 2 };
        let dual = spec.instantiate(&TopologySpec::dual_socket_paper());
        assert_eq!(dual, PolicyKind::CoreSpecNuma { avx_cores_per_socket: 2, sockets: 2 });
        assert_eq!(dual.avx_core_count(), 4);
        let single = spec.instantiate(&TopologySpec::single_socket_paper());
        assert_eq!(single.avx_core_count(), 2);
    }

    #[test]
    fn topology_spec_builds_topology() {
        let t = TopologySpec::multi(4, 6).topology();
        assert_eq!(t.n_server_cores(), 24);
        assert_eq!(t.n_sockets(), 4);
        assert_eq!(t.socket_of(23), 3);
    }

    #[test]
    fn hybrid_topology_axis_sets_cfg_and_defaults_stay_homogeneous() {
        // Default axes carry no hybrid spec — the classic expansion is
        // untouched (the matrix-level differential anchor for this PR).
        let classic = ScenarioMatrix::default_sweep(true, 7);
        assert!(classic.cells().iter().all(|c| c.cfg.hybrid.is_none()));

        let spec = TopologySpec::hybrid_8p16e();
        assert_eq!(spec.name, "8P+16E");
        assert_eq!(spec.cores, 24);
        assert_eq!(spec.sockets, 1);

        let mut m = ScenarioMatrix::default_sweep(true, 7);
        m.topologies = vec![TopologySpec::single_socket_paper(), spec];
        m.policies = vec![PolicySpec::ClassNative { p_cores: 8 }];
        m.isas = vec![Isa::Avx512];
        let cells = m.cells();
        assert_eq!(cells.len(), 2);
        assert!(cells[0].cfg.hybrid.is_none());
        let h = cells[1].cfg.hybrid.expect("hybrid cell must carry the spec");
        assert_eq!((h.p_cores, h.e_cores, h.module_size), (8, 16, 4));
        assert_eq!(cells[1].cfg.cores, 24);
        assert_eq!(cells[1].topology, "8P+16E");
        assert_eq!(cells[1].policy, "class-native(8)");
        assert_eq!(
            cells[1].cfg.policy,
            PolicyKind::ClassNative { p_cores: 8 },
            "class-native instantiates to the hardware-partition policy"
        );
    }
}
