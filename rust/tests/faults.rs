//! Four-band robustness suite for the deterministic fault layer
//! (`avxfreq::faults`), mirroring the executor suite's structure:
//!
//! 1. **Faults-disabled differential** — a disabled `[faults]` config
//!    (even one carrying a fully populated chaos schedule) must take
//!    the literal pre-PR code paths: the open-loop hierarchy reproduces
//!    the flat fleet's bytes, the closed loop renders byte-identically
//!    to a default (empty) fault config, and the scenario matrix with
//!    an explicit `faults = [None]` axis renders the same bytes as the
//!    default expansion.
//! 2. **Determinism** — with the chaos schedule *enabled*, open- and
//!    closed-loop runs render byte-identical reports at 1 and 4 OS
//!    threads (the fault timeline is expanded once up front and only
//!    read by the workers).
//! 3. **Mechanism forcing** — each fault kind demonstrably drives its
//!    feedback path: a crash ejects the dark machine and readmits it
//!    (MTTR > 0), a degradation steals load away from the slow
//!    machine, link faults feed known timeouts into the retry loop.
//! 4. **Golden snapshots** — `metrics::fault_report` and the faulttol
//!    table pin their formatting on synthetic rows
//!    (`UPDATE_GOLDEN=1 cargo test --test faults` regenerates).
//!
//! Triage rule: when a band-1 test fails, the bug is in the fault
//! layer's gating, never in the fault-free reference — do not "fix"
//! the flat fleet or the open loop to match.

use avxfreq::faults::{
    CrashFault, DegradeFault, DegradeScope, FaultWindowStat, FaultsCfg, LinkFault, Schedule,
};
use avxfreq::fleet::{
    run_fleet, run_hier_fleet, BalancerCfg, FleetCfg, HierFleetCfg, HierFleetRun, RouterSpec,
};
use avxfreq::metrics::{fault_report, hier_report};
use avxfreq::repro::faulttol::{self, TolRow};
use avxfreq::scenario::{ArrivalSpec, FaultSpec, PolicySpec, ScenarioMatrix, TopologySpec, WorkloadSpec};
use avxfreq::sched::PolicyKind;
use avxfreq::sim::MS;
use avxfreq::traffic::{ArrivalProcess, FaultOutcomes};
use avxfreq::workload::client::LoadMode;
use avxfreq::workload::crypto::Isa;
use avxfreq::workload::webserver::WebCfg;

// ---------------------------------------------------------------------
// Shared helpers
// ---------------------------------------------------------------------

/// Per-machine scenario (same shape as `hierfleet.rs`): small enough
/// for suite time, loaded enough that fault windows always have
/// traffic to damage.
fn small_cfg(seed: u64) -> WebCfg {
    let mut c = WebCfg::paper_default(Isa::Avx512, PolicyKind::CoreSpec { avx_cores: 1 });
    c.cores = 4;
    c.workers = 8;
    c.page_bytes = 8 * 1024;
    c.warmup = 120 * MS;
    c.measure = 300 * MS;
    c.seed = seed;
    c.mode = LoadMode::OpenProcess { process: ArrivalProcess::two_tenant(30_000.0, 0.3) };
    c
}

fn hier(machines: usize, balancer: BalancerCfg, seed: u64) -> HierFleetCfg {
    let fleet = FleetCfg::new(machines, RouterSpec::RoundRobin, small_cfg(seed));
    let mut h = HierFleetCfg::new(fleet, balancer);
    h.machines_per_rack = 2;
    h
}

/// The chaos preset with the master switch off: every schedule
/// populated, nothing active. The band-1 differential runs on this
/// (not on an empty config) so it proves the fault branches gate on
/// [`FaultsCfg::active`], not on the schedules happening to be empty.
fn chaos_disabled(measure: u64, machines: usize) -> FaultsCfg {
    let mut f = FaultsCfg::chaos(measure, machines);
    f.enabled = false;
    f
}

fn tail_bits(h: &HierFleetRun) -> Vec<u64> {
    [
        h.tail.mean_us,
        h.tail.p50_us,
        h.tail.p95_us,
        h.tail.p99_us,
        h.tail.p999_us,
        h.tail.max_us,
        h.tail.slo_violation_frac,
    ]
    .iter()
    .map(|f| f.to_bits())
    .collect()
}

fn renders(h: &HierFleetRun) -> String {
    let mut s = hier_report(&[("fleet", h)]).render();
    s.push_str(&fault_report(&h.fault_windows, &h.fault_outcomes).render());
    s
}

// ---------------------------------------------------------------------
// Band 1 — the faults-disabled differential (acceptance criterion)
// ---------------------------------------------------------------------

/// Open loop, disabled chaos schedule: the hierarchy must reproduce
/// the flat fleet's **bytes** — the untouched pre-PR path.
#[test]
fn disabled_faults_open_loop_reproduces_flat_fleet_bytes() {
    let mut hcfg = hier(5, BalancerCfg::default(), 0xFA01);
    hcfg.faults = chaos_disabled(hcfg.fleet.cfg.measure, 5);
    assert!(!hcfg.faults.active(), "disabled schedule must not be active");
    assert!(!hcfg.faults.crashes.is_empty(), "the schedule must be populated");

    let flat = run_fleet(&hcfg.fleet, 4);
    let h = run_hier_fleet(&hcfg, 4);
    assert_eq!(h.completed, flat.completed, "completed");
    assert_eq!(h.dropped, flat.dropped, "dropped");
    assert_eq!(h.violations, flat.violations, "exact SLO violations");
    let flat_bits: Vec<u64> = [
        flat.tail.mean_us,
        flat.tail.p50_us,
        flat.tail.p95_us,
        flat.tail.p99_us,
        flat.tail.p999_us,
        flat.tail.max_us,
        flat.tail.slo_violation_frac,
    ]
    .iter()
    .map(|f| f.to_bits())
    .collect();
    assert_eq!(tail_bits(&h), flat_bits, "cluster tail must be bit-identical");
    assert!(h.fault_outcomes.is_noop(), "no fault accounting: {:?}", h.fault_outcomes);
    assert!(h.fault_windows.is_empty(), "no fault windows to report");
}

/// Closed loop: a disabled chaos schedule renders byte-identically to
/// the default (empty) fault config — retries, hedges, and ejections
/// all active in both.
#[test]
fn disabled_faults_closed_loop_matches_default_config_bytes() {
    let empty = hier(4, BalancerCfg::closed(), 0xFA02);
    let mut loaded = hier(4, BalancerCfg::closed(), 0xFA02);
    loaded.faults = chaos_disabled(loaded.fleet.cfg.measure, 4);

    let a = run_hier_fleet(&empty, 4);
    let b = run_hier_fleet(&loaded, 4);
    assert_eq!(renders(&a), renders(&b), "disabled schedule changed the closed loop's bytes");
    assert_eq!(a.outcomes, b.outcomes, "front-end outcome counters differ");
    assert_eq!(a.fault_outcomes, b.fault_outcomes);
    assert!(b.fault_outcomes.is_noop());
    assert!(b.fault_windows.is_empty());
}

fn tiny_matrix(seed: u64) -> ScenarioMatrix {
    let mut m = ScenarioMatrix::new(seed);
    m.topologies = vec![TopologySpec::multi(1, 4)];
    m.policies = vec![PolicySpec::CoreSpec { avx_cores: 1 }];
    m.workloads = vec![WorkloadSpec {
        name: "small".to_string(),
        compress: true,
        page_kib: 8,
        rate_per_core: 4_000.0,
    }];
    m.isas = vec![Isa::Avx512];
    m.loads = vec![1.0];
    m.arrivals = vec![ArrivalSpec::Poisson];
    m.warmup = 80 * MS;
    m.measure = 160 * MS;
    m
}

/// Matrix level: spelling out `faults = [None]` must be the identity —
/// same cell count, same labels, same rendered bytes as the default
/// expansion, and no cell takes the hierarchical path for it.
#[test]
fn matrix_explicit_none_faults_axis_is_the_identity() {
    let default_run = tiny_matrix(0x7A12).run(2);
    let mut m = tiny_matrix(0x7A12);
    m.faults = vec![FaultSpec::None];
    let explicit_run = m.run(2);

    assert_eq!(default_run.render(), explicit_run.render(), "matrix table differs");
    assert_eq!(default_run.render_tail(), explicit_run.render_tail(), "tail table differs");
    for c in &explicit_run.cells {
        assert_eq!(c.scenario.faults, FaultSpec::None);
        assert!(!c.scenario.label().contains("chaos"), "label: {}", c.scenario.label());
        assert!(c.hier.is_none(), "fault-free single-machine cell must not go hierarchical");
    }
}

// ---------------------------------------------------------------------
// Band 2 — determinism with faults enabled (acceptance criterion)
// ---------------------------------------------------------------------

/// Closed loop under the full chaos schedule: byte-identical hier and
/// fault reports at 1 and 4 OS threads, identical counters.
#[test]
fn faulted_closed_loop_byte_identical_across_threads() {
    let mut hcfg = hier(4, BalancerCfg::closed(), 0xFA03);
    hcfg.faults = FaultsCfg::chaos(hcfg.fleet.cfg.measure, 4);
    let serial = run_hier_fleet(&hcfg, 1);
    let parallel = run_hier_fleet(&hcfg, 4);
    assert_eq!(renders(&serial), renders(&parallel), "1 vs 4 threads differ under faults");
    assert_eq!(serial.outcomes, parallel.outcomes);
    assert_eq!(serial.fault_outcomes, parallel.fault_outcomes);
    assert_eq!(serial.fault_windows, parallel.fault_windows);
    assert!(!parallel.fault_outcomes.is_noop(), "chaos schedule must leave a mark");
    let digest_key = |h: &HierFleetRun| -> Vec<(u64, u64, u64)> {
        h.digests.iter().map(|d| (d.arrivals, d.completed, d.timeouts)).collect()
    };
    assert_eq!(digest_key(&serial), digest_key(&parallel), "per-machine digests differ");
}

/// Open loop under the same schedule: the segment-splitting path is
/// thread-count-invariant too.
#[test]
fn faulted_open_loop_byte_identical_across_threads() {
    let mut hcfg = hier(4, BalancerCfg::default(), 0xFA04);
    hcfg.faults = FaultsCfg::chaos(hcfg.fleet.cfg.measure, 4);
    let serial = run_hier_fleet(&hcfg, 1);
    let parallel = run_hier_fleet(&hcfg, 4);
    assert_eq!(serial.completed, parallel.completed);
    assert_eq!(serial.violations, parallel.violations);
    assert_eq!(serial.dropped, parallel.dropped);
    assert_eq!(tail_bits(&serial), tail_bits(&parallel), "tail must be bit-identical");
    assert_eq!(serial.fault_outcomes, parallel.fault_outcomes);
    assert!(
        serial.fault_outcomes.lost_to_crash > 0 || serial.fault_outcomes.dropped_by_net > 0,
        "chaos must cost the open loop something: {:?}",
        serial.fault_outcomes
    );
}

// ---------------------------------------------------------------------
// Band 3 — each fault kind forces its mechanism
// ---------------------------------------------------------------------

/// A crash that takes one machine dark for a whole epoch must be
/// *seen* by the closed loop: majority loss ⇒ ejection, the idle
/// ejected machine ⇒ readmission, and the epochs in between are the
/// published MTTR.
#[test]
fn crash_forces_ejection_then_readmission() {
    let mut hcfg = hier(4, BalancerCfg::closed(), 0xFA05);
    let mut f = FaultsCfg { enabled: true, ..Default::default() };
    // Epochs are 75 ms (300 ms / 4); [70, 155) covers epoch [75, 150)
    // entirely, so every request routed to m1 there is lost.
    f.crashes.push(CrashFault {
        machine: 1,
        schedule: Schedule::OneShot { at: 70 * MS },
        down: 85 * MS,
        cold_start: 0,
    });
    f.validate(hcfg.fleet.cfg.measure, 4).expect("crash schedule must validate");
    hcfg.faults = f;

    let h = run_hier_fleet(&hcfg, 4);
    let fo = &h.fault_outcomes;
    assert_eq!(fo.crash_windows, 1);
    assert!(fo.lost_to_crash > 0, "a dark epoch must lose requests");
    assert!(fo.fault_retries > 0, "known losses must feed the retry loop");
    assert!(h.outcomes.ejections >= 1, "majority loss must eject the dark machine");
    assert!(h.outcomes.readmissions >= 1, "the recovered machine must be readmitted");
    assert!(fo.recovery_epochs >= 1, "ejection→readmission gap is the MTTR");
    let crash_row = h
        .fault_windows
        .iter()
        .find(|w| w.kind == "crash")
        .expect("the crash window must be reported");
    assert_eq!(crash_row.machine, "m1");
    assert!(crash_row.readmit_epochs >= 1, "the crash row publishes the MTTR");
}

/// A machine degraded to 35% frequency for the whole run reads as a
/// tail outlier, so the health view steals its traffic: ejection fires
/// and the machine sits out epochs.
#[test]
fn degradation_steals_load_away() {
    let mut bal = BalancerCfg::closed();
    bal.hedge_p99_mult = 0.0; // isolate the ejection signal
    bal.eject_factor = 1.5;
    let mut hcfg = hier(4, bal, 0xFA06);
    let measure = hcfg.fleet.cfg.measure;
    let mut f = FaultsCfg { enabled: true, ..Default::default() };
    f.degrades.push(DegradeFault {
        machine: 2,
        scope: DegradeScope::Machine,
        scale: 0.35,
        schedule: Schedule::OneShot { at: 0 },
        dur: measure,
    });
    f.validate(measure, 4).expect("degrade schedule must validate");
    hcfg.faults = f;

    let h = run_hier_fleet(&hcfg, 4);
    assert!(h.fault_outcomes.degrade_windows >= 1);
    assert!(h.outcomes.ejections >= 1, "a ~3x-slower machine must trip the 1.5x ejector");
    assert!(
        h.digests[2].epochs_ejected >= 1,
        "the degraded machine must sit out epochs: {:?}",
        h.digests[2]
    );
    assert!(h.fault_windows.iter().any(|w| w.kind == "degrade" && w.machine == "m2"));
}

/// Link faults (drops) on every machine feed *known* timeouts into the
/// retry machinery — the front end saw the requests vanish.
#[test]
fn link_drops_feed_known_timeouts_into_retries() {
    let mut hcfg = hier(4, BalancerCfg::closed(), 0xFA07);
    let measure = hcfg.fleet.cfg.measure;
    let mut f = FaultsCfg { enabled: true, ..Default::default() };
    f.links.push(LinkFault {
        machine: None,
        delay: 150 * avxfreq::sim::US,
        drop_frac: 0.3,
        schedule: Schedule::OneShot { at: 0 },
        dur: measure,
    });
    f.validate(measure, 4).expect("link schedule must validate");
    hcfg.faults = f;

    let h = run_hier_fleet(&hcfg, 4);
    assert!(h.fault_outcomes.dropped_by_net > 0, "30% drops must be observed");
    assert!(h.fault_outcomes.fault_retries > 0, "drops must re-enter as retries");
    assert!(h.completed > 0, "the fleet must keep serving through the fault");
    assert!(
        h.fault_windows.iter().any(|w| w.kind == "link" && w.machine == "all"),
        "an every-machine link fault collapses to one `all` row: {:?}",
        h.fault_windows
    );
}

// ---------------------------------------------------------------------
// Band 4 — golden snapshots (formatting contracts)
// ---------------------------------------------------------------------

fn check_golden(name: &str, actual: &str) {
    let path = format!("{}/rust/tests/golden/{name}.txt", env!("CARGO_MANIFEST_DIR"));
    if std::env::var("UPDATE_GOLDEN").is_ok() {
        std::fs::write(&path, actual).expect("write golden");
        eprintln!("updated {path}");
        return;
    }
    let expected = std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("{path}: {e}"));
    assert!(
        actual == expected,
        "{name} drifted from its snapshot ({path}).\n--- expected ---\n{expected}\n--- actual ---\n{actual}\n\
         Run with UPDATE_GOLDEN=1 if the change is intentional."
    );
}

#[test]
fn fault_report_matches_snapshot() {
    let windows = vec![
        FaultWindowStat {
            kind: "crash",
            machine: "m1".to_string(),
            start: 40 * MS,
            end: 55 * MS,
            p99_in_us: 8_000.0,
            p99_out_us: 2_000.0,
            violations_in: 125,
            readmit_epochs: 2,
        },
        FaultWindowStat {
            kind: "degrade",
            machine: "m0".to_string(),
            start: 10 * MS,
            end: 30 * MS,
            p99_in_us: 4_500.0,
            p99_out_us: 2_000.0,
            violations_in: 60,
            readmit_epochs: 0,
        },
        FaultWindowStat {
            kind: "link",
            machine: "all".to_string(),
            start: 120 * MS,
            end: 132 * MS + MS / 2,
            p99_in_us: 3_250.0,
            p99_out_us: 2_000.0,
            violations_in: 40,
            readmit_epochs: 0,
        },
    ];
    let outcomes = FaultOutcomes {
        lost_to_crash: 75,
        dropped_by_net: 18,
        fault_retries: 93,
        crash_windows: 1,
        degrade_windows: 1,
        recovery_epochs: 2,
    };
    check_golden("fault_report", &fault_report(&windows, &outcomes).render());
}

#[test]
fn faulttol_report_matches_snapshot() {
    // Values exactly representable at the printed precision so the
    // rendering is independent of float-rounding ties.
    let rows = vec![
        TolRow {
            policy: "unmodified".to_string(),
            governor: "intel-legacy".to_string(),
            clean_p99_us: 2_000.0,
            open_fault_p99_us: 8_000.0,
            closed_fault_p99_us: 3_500.0,
            lost: 75,
            retries: 93,
            mttr_epochs: 2,
            recovered_pct: faulttol::recovered_pct(2_000.0, 8_000.0, 3_500.0),
        },
        TolRow {
            policy: "core-spec(2)".to_string(),
            governor: "dim-silicon".to_string(),
            clean_p99_us: 1_500.0,
            open_fault_p99_us: 6_000.0,
            closed_fault_p99_us: 2_400.0,
            lost: 40,
            retries: 51,
            mttr_epochs: 1,
            recovered_pct: faulttol::recovered_pct(1_500.0, 6_000.0, 2_400.0),
        },
    ];
    assert_eq!(rows[0].recovered_pct, 75.0, "(8000-3500)/(8000-2000)");
    assert_eq!(rows[1].recovered_pct, 80.0, "(6000-2400)/(6000-1500)");
    check_golden("faulttol_report", &faulttol::table(&rows).render());
}

#[test]
fn recovered_pct_handles_zero_and_negative_damage() {
    // No damage → nothing to recover (never a division blow-up).
    assert_eq!(faulttol::recovered_pct(2_000.0, 2_000.0, 1_500.0), 0.0);
    // A closed loop that made things *worse* reads as negative.
    assert_eq!(faulttol::recovered_pct(1_000.0, 3_000.0, 3_500.0), -25.0);
}
