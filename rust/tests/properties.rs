//! Property-based tests over the scheduler, frequency model, and
//! simulator invariants (using the in-repo `testkit`).

use avxfreq::cpu::freq::{FreqParams, License, LicenseState};
use avxfreq::isa::block::{Block, ClassMix, InsnClass};
use avxfreq::sched::machine::{Action, Machine, MachineParams, NullDriver, TaskBody};
use avxfreq::sched::{PolicyKind, TaskType};
use avxfreq::sim::{Time, SEC, US};
use avxfreq::testkit::{assert_prop, IntRange, Strategy, VecOf};
use avxfreq::util::Rng;
use std::cell::RefCell;
use std::rc::Rc;

/// Randomized task body: a program of phases. Odd-encoded phases are AVX
/// regions wrapped in with_avx()/without_avx(); the rest are scalar.
struct RandomBody {
    /// (set_type_before, class, insns) triples flattened to steps.
    steps: Vec<Action>,
    idx: usize,
    completed: Rc<RefCell<u64>>,
}

fn build_steps(encoded: &[u64], task_salt: usize) -> Vec<Action> {
    let mut steps = Vec::new();
    for (i, &x) in encoded.iter().enumerate() {
        let insns = (x >> 1).max(1);
        let is_avx = (i + task_salt) % 3 == 0 && x & 1 == 1;
        if is_avx {
            steps.push(Action::SetType(TaskType::Avx));
            steps.push(Action::Run {
                block: Block {
                    mix: ClassMix::of(InsnClass::Avx512Heavy, insns),
                    mem_ops: 0,
                    branches: insns / 50,
                    license_exempt: false,
                },
                func: i as u64,
                stack: 0,
            });
            steps.push(Action::SetType(TaskType::Scalar));
        } else {
            steps.push(Action::Run {
                block: Block {
                    mix: ClassMix::scalar(insns),
                    mem_ops: 0,
                    branches: insns / 50,
                    license_exempt: false,
                },
                func: 100 + i as u64,
                stack: 0,
            });
        }
    }
    steps
}

impl TaskBody for RandomBody {
    fn next(&mut self, _now: Time, _rng: &mut Rng) -> Action {
        if self.idx >= self.steps.len() {
            *self.completed.borrow_mut() += 1;
            return Action::Exit;
        }
        let a = self.steps[self.idx].clone();
        self.idx += 1;
        a
    }
}

/// Strategy: a list of phase encodings (bit 0 = avx candidate, rest = insns).
struct PhaseList;
impl Strategy for PhaseList {
    type Value = Vec<u64>;
    fn generate(&self, rng: &mut Rng) -> Vec<u64> {
        VecOf { elem: IntRange { lo: 1000, hi: 200_000 }, max_len: 24 }.generate(rng)
    }
    fn simplify(&self, v: &Vec<u64>) -> Vec<Vec<u64>> {
        VecOf { elem: IntRange { lo: 1000, hi: 200_000 }, max_len: 24 }.simplify(v)
    }
}

fn run_machine(phases: &[u64], policy: PolicyKind, seed: u64) -> (Machine, u64) {
    let mut p = MachineParams::new(4, policy);
    p.seed = seed;
    let mut m = Machine::new(p);
    let completed = Rc::new(RefCell::new(0u64));
    for t in 0..6 {
        m.spawn(
            TaskType::Scalar,
            0,
            Box::new(RandomBody {
                steps: build_steps(phases, t),
                idx: 0,
                completed: completed.clone(),
            }),
        );
    }
    m.run_until(30 * SEC, &mut NullDriver);
    let done = *completed.borrow();
    (m, done)
}

#[test]
fn prop_scalar_cores_never_execute_avx() {
    assert_prop("scalar cores stay clean", 0xA11CE, 20, &PhaseList, |phases| {
        let (m, done) = run_machine(phases, PolicyKind::CoreSpec { avx_cores: 1 }, 7);
        if done != 6 {
            return Err(format!("only {done}/6 tasks completed"));
        }
        for c in 0..3 {
            let perf = &m.cores[c].perf;
            if perf.license_cycles[1] + perf.license_cycles[2] > 0 {
                return Err(format!(
                    "scalar core {c} accumulated licensed cycles {:?}",
                    perf.license_cycles
                ));
            }
            if perf.license_requests > 0 {
                return Err(format!("scalar core {c} requested a license"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_all_tasks_complete_under_every_policy() {
    for policy in [
        PolicyKind::Unmodified,
        PolicyKind::CoreSpec { avx_cores: 1 },
        PolicyKind::CoreSpec { avx_cores: 3 },
        PolicyKind::StrictPartition { avx_cores: 1 },
    ] {
        assert_prop("no starvation", 0xBEEF, 10, &PhaseList, |phases| {
            let (_m, done) = run_machine(phases, policy.clone(), 11);
            if done == 6 {
                Ok(())
            } else {
                Err(format!("{done}/6 under {policy:?}"))
            }
        });
    }
}

#[test]
fn prop_simulation_deterministic() {
    assert_prop("determinism", 0xD00D, 8, &PhaseList, |phases| {
        let (m1, d1) = run_machine(phases, PolicyKind::CoreSpec { avx_cores: 2 }, 99);
        let (m2, d2) = run_machine(phases, PolicyKind::CoreSpec { avx_cores: 2 }, 99);
        let p1 = m1.total_perf();
        let p2 = m2.total_perf();
        if d1 != d2
            || p1.instructions != p2.instructions
            || p1.cycles != p2.cycles
            || p1.busy_ns != p2.busy_ns
            || m1.sched.stats.migrations != m2.sched.stats.migrations
        {
            return Err("same seed, different outcome".to_string());
        }
        Ok(())
    });
}

#[test]
fn prop_work_conservation() {
    // Executed workload instructions equal what the bodies submitted —
    // nothing lost or duplicated across migrations, suspensions, and
    // preemptions. Kernel overhead (syscalls, picks) is accounted on top,
    // bounded by a few percent.
    assert_prop("work conservation", 0xC0DE, 12, &PhaseList, |phases| {
        let (m, done) = run_machine(phases, PolicyKind::CoreSpec { avx_cores: 1 }, 3);
        if done != 6 {
            return Err(format!("{done}/6 completed"));
        }
        let per_task: u64 = phases.iter().map(|&x| (x >> 1).max(1)).sum();
        let expected = 6 * per_task;
        let got = m.total_perf().instructions;
        if got < expected {
            return Err(format!("instructions {got} < submitted {expected} — work lost"));
        }
        if got > expected + expected / 10 + 200_000 {
            return Err(format!("instructions {got} ≫ submitted {expected} — double counting"));
        }
        Ok(())
    });
}

// ---- adaptive AVX-core controller ------------------------------------------

/// Task whose AVX duty cycle follows a generated load trace: the trace
/// holds one duty percentage per 100 ms window, so random traces exercise
/// ramps, spikes, and dead periods against the §3.1 controller.
struct TraceDuty {
    trace: Rc<Vec<u64>>,
    window: Time,
    i: u64,
    phase: u8,
}

impl TaskBody for TraceDuty {
    fn next(&mut self, now: Time, _rng: &mut Rng) -> Action {
        let duty = if self.trace.is_empty() {
            0
        } else {
            let w = (now / self.window) as usize;
            self.trace[w.min(self.trace.len() - 1)]
        };
        self.i += 1;
        let avx_turn = self.i % 100 < duty;
        match (self.phase, avx_turn) {
            (0, true) => {
                self.phase = 1;
                Action::SetType(TaskType::Avx)
            }
            (1, _) => {
                self.phase = 2;
                Action::Run {
                    block: Block {
                        mix: ClassMix::of(InsnClass::Avx512Heavy, 40_000),
                        mem_ops: 0,
                        branches: 80,
                        license_exempt: false,
                    },
                    func: 1,
                    stack: 0,
                }
            }
            (2, _) => {
                self.phase = 0;
                Action::SetType(TaskType::Scalar)
            }
            _ => Action::Run {
                block: Block {
                    mix: ClassMix::scalar(40_000),
                    mem_ops: 0,
                    branches: 80,
                    license_exempt: false,
                },
                func: 2,
                stack: 0,
            },
        }
    }
}

/// Satellite invariant for `sched/adaptive.rs`: under ANY load trace the
/// AVX-core count stays within `[min_avx, min(max_avx, n-1)]` after every
/// tick, and the two-window debounce means the count never changes at two
/// consecutive ticks (hysteresis stability). Failing traces shrink to a
/// minimal counterexample via the testkit's `VecOf` strategy.
#[test]
fn prop_adaptive_bounds_and_hysteresis() {
    use avxfreq::sched::adaptive::{AdaptiveParams, Controller};
    let strat = VecOf { elem: IntRange { lo: 0, hi: 101 }, max_len: 10 };
    assert_prop("adaptive bounds + hysteresis", 0xADA9, 8, &strat, |trace| {
        let n_cores = 8;
        let params = AdaptiveParams::default();
        let mut p = MachineParams::new(n_cores, PolicyKind::CoreSpec { avx_cores: 2 });
        p.seed = 0xBEE5;
        let mut m = Machine::new(p);
        let shared = Rc::new(trace.clone());
        for _ in 0..12 {
            m.spawn(
                TaskType::Scalar,
                0,
                Box::new(TraceDuty {
                    trace: shared.clone(),
                    window: SEC / 10,
                    i: 0,
                    phase: 0,
                }),
            );
        }
        let mut ctl = Controller::new(params, n_cores);
        let mut t = 0;
        let mut ks = Vec::new();
        while t < SEC {
            t += params.interval;
            m.run_until(t, &mut avxfreq::sched::machine::NullDriver);
            ks.push(ctl.tick(&mut m));
        }
        let hi = params.max_avx.min(n_cores - 1);
        for (i, &k) in ks.iter().enumerate() {
            if k < params.min_avx || k > hi {
                return Err(format!(
                    "tick {i}: k={k} outside [{}, {hi}] (trace {trace:?})",
                    params.min_avx
                ));
            }
        }
        for w in ks.windows(3) {
            if w[0] != w[1] && w[1] != w[2] {
                return Err(format!(
                    "count changed at two consecutive ticks: {w:?} — debounce broken"
                ));
            }
        }
        let changes = ks.windows(2).filter(|w| w[0] != w[1]).count() as u64;
        if changes != ctl.grows + ctl.shrinks {
            return Err(format!(
                "reported {} resizes but observed {changes}",
                ctl.grows + ctl.shrinks
            ));
        }
        Ok(())
    });
}

// ---- frequency state machine properties -----------------------------------

#[test]
fn prop_license_hysteresis() {
    // Licenses may only relax after a full hold window of lower demand.
    let steps = VecOf { elem: IntRange { lo: 0, hi: 3 * 400 }, max_len: 200 };
    assert_prop("license hysteresis", 0xF00D, 50, &steps, |seq| {
        let params = FreqParams::default();
        let hold = params.hold;
        let mut m = LicenseState::new(params);
        let mut now: Time = 0;
        let mut last_at_or_above: [Time; 3] = [0; 3];
        let mut prev_granted = License::L0;
        for &enc in seq {
            let demand = License::from_index((enc % 3) as usize);
            now += 20 * US + (enc / 3) as Time * 10;
            let _ = m.observe(now, demand);
            for lvl in 0..=demand.index() {
                last_at_or_above[lvl] = now;
            }
            let granted = m.granted();
            if granted < prev_granted {
                let since = now.saturating_sub(last_at_or_above[prev_granted.index()]);
                if since < hold && last_at_or_above[prev_granted.index()] != now {
                    return Err(format!(
                        "relaxed {prev_granted:?}→{granted:?} only {since}ns after matching \
                         demand (hold {hold}ns)"
                    ));
                }
            }
            prev_granted = granted;
        }
        Ok(())
    });
}

#[test]
fn prop_license_state_machine_total() {
    // Long random walks never produce invalid effective states.
    let steps = VecOf { elem: IntRange { lo: 0, hi: 3 * 1000 }, max_len: 400 };
    assert_prop("license machine total", 0x50DA, 30, &steps, |seq| {
        let mut m = LicenseState::new(FreqParams::default());
        let mut now = 0;
        for &enc in seq {
            now += (enc / 3) as Time;
            let s = m.observe(now, License::from_index((enc % 3) as usize));
            if s.ipc_factor <= 0.0 || s.ipc_factor > 1.0 {
                return Err(format!("bad ipc factor {}", s.ipc_factor));
            }
        }
        Ok(())
    });
}

// ---- histogram property ----------------------------------------------------

#[test]
fn prop_histogram_percentile_error_bounded() {
    use avxfreq::util::LogHistogram;
    let strat = VecOf { elem: IntRange { lo: 1, hi: 50_000_000 }, max_len: 400 };
    assert_prop("histogram error bound", 0x9151, 30, &strat, |values| {
        if values.is_empty() {
            return Ok(());
        }
        let mut h = LogHistogram::new();
        for &v in values {
            h.record(v);
        }
        let mut sorted = values.clone();
        sorted.sort_unstable();
        for p in [50.0, 90.0, 99.0] {
            let approx = h.percentile(p) as f64;
            let rank = ((p / 100.0) * values.len() as f64).ceil().max(1.0) as usize - 1;
            let exact = sorted[rank.min(sorted.len() - 1)] as f64;
            if approx > exact + 1.0 {
                return Err(format!("p{p}: approx {approx} > exact {exact}"));
            }
            if exact > 32.0 && approx < exact * 0.90 {
                return Err(format!("p{p}: approx {approx} too far below exact {exact}"));
            }
        }
        Ok(())
    });
}

// ---- fault-and-migrate invariant -------------------------------------------

#[test]
fn prop_fault_migrate_keeps_scalar_cores_clean() {
    struct Unannotated {
        n: u64,
    }
    impl TaskBody for Unannotated {
        fn next(&mut self, _now: Time, _rng: &mut Rng) -> Action {
            if self.n == 0 {
                return Action::Exit;
            }
            self.n -= 1;
            let wide = self.n % 7 == 0;
            Action::Run {
                block: Block {
                    mix: ClassMix::of(
                        if wide { InsnClass::Avx512Heavy } else { InsnClass::Scalar },
                        30_000,
                    ),
                    mem_ops: 0,
                    branches: 100,
                    license_exempt: false,
                },
                func: self.n % 5,
                stack: 0,
            }
        }
    }
    let seeds = IntRange { lo: 1, hi: 100_000 };
    assert_prop("fault-migrate clean scalar cores", 0xFA17, 8, &seeds, |&seed| {
        let mut p = MachineParams::new(4, PolicyKind::CoreSpec { avx_cores: 1 });
        p.seed = seed;
        p.fault_migrate = Some(Default::default());
        let mut m = Machine::new(p);
        for _ in 0..5 {
            m.spawn(TaskType::Scalar, 0, Box::new(Unannotated { n: 150 }));
        }
        m.run_until(20 * SEC, &mut NullDriver);
        if m.fm_faults == 0 {
            return Err("no faults recorded".into());
        }
        for c in 0..3 {
            if m.cores[c].perf.license_cycles[2] > 0 {
                return Err(format!("scalar core {c} ran AVX-512 cycles"));
            }
        }
        Ok(())
    });
}

// ---- fairness ---------------------------------------------------------------

#[test]
fn prop_quantum_fairness_on_oversubscribed_core() {
    struct Spin;
    impl TaskBody for Spin {
        fn next(&mut self, _now: Time, _rng: &mut Rng) -> Action {
            Action::Run {
                block: Block {
                    mix: ClassMix::scalar(50_000),
                    mem_ops: 0,
                    branches: 100,
                    license_exempt: false,
                },
                func: 1,
                stack: 0,
            }
        }
    }
    let seeds = IntRange { lo: 1, hi: 1 << 30 };
    assert_prop("quantum fairness", 0xFA13, 5, &seeds, |&seed| {
        let mut p = MachineParams::new(1, PolicyKind::Unmodified);
        p.seed = seed;
        let mut m = Machine::new(p);
        let ids: Vec<_> =
            (0..2).map(|_| m.spawn(TaskType::Untyped, 0, Box::new(Spin))).collect();
        m.run_until(2 * SEC, &mut NullDriver);
        let a = m.sched.entity(ids[0]).cpu_ns as f64;
        let b = m.sched.entity(ids[1]).cpu_ns as f64;
        let ratio = a.max(b) / a.min(b).max(1.0);
        if ratio > 1.25 {
            return Err(format!("unfair split {a} vs {b}"));
        }
        Ok(())
    });
}
