//! Integration tests for the hierarchical closed-loop fleet layer:
//! merge laws for the *streaming* machine → rack → cluster aggregation
//! (absorbing runs as they finish, in any completion order, must equal
//! a concatenated single-pass merge), the feedback-disabled
//! differential (the open-loop hierarchy reproduces the flat fleet's
//! bytes exactly), closed-loop determinism across OS thread counts,
//! each feedback mechanism demonstrably firing, and the O(machines)
//! memory shape that makes wide sweeps possible.

use avxfreq::fleet::{
    run_fleet, run_hier_fleet, BalancerCfg, FleetCfg, HierFleetCfg, HierFleetRun, HierarchyAgg,
    MachineDigest, RouterSpec,
};
use avxfreq::metrics::hier_report;
use avxfreq::sched::PolicyKind;
use avxfreq::sim::MS;
use avxfreq::testkit::{assert_prop, IntRange, VecOf};
use avxfreq::traffic::{ArrivalProcess, LatencyStats, TailSummary};
use avxfreq::workload::client::LoadMode;
use avxfreq::workload::crypto::Isa;
use avxfreq::workload::webserver::{WebCfg, WebRun};

// ---------------------------------------------------------------------
// Shared helpers
// ---------------------------------------------------------------------

fn summary_eq(a: &TailSummary, b: &TailSummary) -> Result<(), String> {
    if a.completed != b.completed {
        return Err(format!("completed {} != {}", a.completed, b.completed));
    }
    let pairs = [
        (a.mean_us, b.mean_us),
        (a.p50_us, b.p50_us),
        (a.p95_us, b.p95_us),
        (a.p99_us, b.p99_us),
        (a.p999_us, b.p999_us),
        (a.max_us, b.max_us),
        (a.slo_us, b.slo_us),
        (a.slo_violation_frac, b.slo_violation_frac),
    ];
    for (x, y) in pairs {
        if x != y {
            return Err(format!("summary field {x} != {y}"));
        }
    }
    Ok(())
}

/// Recorder equality through the whole query surface: exact counters
/// plus the frozen summary (which exercises the histogram percentiles).
fn stats_eq(a: &LatencyStats, b: &LatencyStats) -> Result<(), String> {
    if a.completed() != b.completed() {
        return Err(format!("completed {} != {}", a.completed(), b.completed()));
    }
    if a.violations() != b.violations() {
        return Err(format!("violations {} != {}", a.violations(), b.violations()));
    }
    for v in [0, 100, 10_000, 1_000_000, u64::MAX / 2] {
        if a.hist.fraction_above(v) != b.hist.fraction_above(v) {
            return Err(format!("fraction_above({v}) differs"));
        }
    }
    summary_eq(&a.summary(), &b.summary())
}

fn stats_of(samples: &[u64], slo: u64) -> LatencyStats {
    let mut s = LatencyStats::new(slo);
    for &v in samples {
        s.record(v);
    }
    s
}

/// The per-machine scenario used by the end-to-end tests: small enough
/// to run in suite time, loaded enough that every mechanism has tail
/// mass to work with.
fn small_cfg(seed: u64) -> WebCfg {
    let mut c = WebCfg::paper_default(Isa::Avx512, PolicyKind::CoreSpec { avx_cores: 1 });
    c.cores = 4;
    c.workers = 8;
    c.page_bytes = 8 * 1024;
    c.warmup = 120 * MS;
    c.measure = 300 * MS;
    c.seed = seed;
    c.mode = LoadMode::OpenProcess { process: ArrivalProcess::two_tenant(30_000.0, 0.3) };
    c
}

fn hier(machines: usize, balancer: BalancerCfg, seed: u64) -> HierFleetCfg {
    let fleet = FleetCfg::new(machines, RouterSpec::RoundRobin, small_cfg(seed));
    let mut h = HierFleetCfg::new(fleet, balancer);
    h.machines_per_rack = 2;
    h
}

// ---------------------------------------------------------------------
// Streaming-aggregation merge laws (satellite: property tests)
// ---------------------------------------------------------------------

/// Build a synthetic machine run holding `samples`, split across two
/// tenants by parity (matching the recorder the aggregation keeps per
/// tenant).
fn synthetic_run(samples: &[u64], slo: u64) -> WebRun {
    let (even, odd): (Vec<u64>, Vec<u64>) = samples.iter().partition(|&&v| v % 2 == 0);
    WebRun {
        stats: stats_of(samples, slo),
        tenant_stats: vec![stats_of(&even, slo), stats_of(&odd, slo)],
        completed: samples.len() as u64,
        dropped: samples.len() as u64 % 3,
        ..WebRun::default()
    }
}

/// The streamed hierarchy merge is order-independent and equals the
/// concatenated single-pass merge: absorbing machine runs as they
/// "finish" — forward or reverse completion order — yields rack,
/// cluster, and tenant recorders identical to recording every sample
/// union directly. Empty machines (no samples) are legal and absorbed
/// without disturbing anything.
#[test]
fn prop_streamed_hier_merge_equals_single_pass() {
    const MACHINES: usize = 5;
    const PER_RACK: usize = 2;
    let slo = 5 * MS;
    let tenants = ["scalar".to_string(), "avx".to_string()];
    let strat = VecOf { elem: IntRange { lo: 1, hi: 40_000_000 }, max_len: 200 };
    assert_prop("streamed hier merge ≡ single pass", 0x41E2, 50, &strat, |samples| {
        // Deterministic machine split covering every sample exactly
        // once; short draws leave the high-index machines empty, so the
        // empty-recorder edge rides along.
        let per: Vec<Vec<u64>> = (0..MACHINES)
            .map(|m| samples.iter().copied().skip(m).step_by(MACHINES).collect())
            .collect();
        let runs: Vec<WebRun> = per.iter().map(|p| synthetic_run(p, slo)).collect();
        let arrivals: Vec<u64> = per.iter().map(|p| p.len() as u64).collect();

        // Streamed, two different completion orders.
        let forward = HierarchyAgg::new(MACHINES, PER_RACK, slo, &tenants);
        for (i, r) in runs.iter().enumerate() {
            forward.absorb(i, r, 1.0);
        }
        let reverse = HierarchyAgg::new(MACHINES, PER_RACK, slo, &tenants);
        for (i, r) in runs.iter().enumerate().rev() {
            reverse.absorb(i, r, 1.0);
        }
        let fsnap = forward.finish(&arrivals);
        let rsnap = reverse.finish(&arrivals);

        // Single pass: record the concatenated samples directly.
        let rack_direct: Vec<LatencyStats> = (0..MACHINES.div_ceil(PER_RACK))
            .map(|r| {
                let union: Vec<u64> = per
                    .iter()
                    .enumerate()
                    .filter(|(i, _)| i / PER_RACK == r)
                    .flat_map(|(_, p)| p.iter().copied())
                    .collect();
                stats_of(&union, slo)
            })
            .collect();
        let cluster_direct = stats_of(samples, slo);

        if fsnap.racks.len() != rack_direct.len() {
            return Err(format!("{} racks != {}", fsnap.racks.len(), rack_direct.len()));
        }
        for (i, (streamed, direct)) in fsnap.racks.iter().zip(&rack_direct).enumerate() {
            stats_eq(streamed, direct).map_err(|e| format!("rack {i}: {e}"))?;
        }
        stats_eq(&fsnap.cluster, &cluster_direct).map_err(|e| format!("cluster: {e}"))?;
        // Per-tenant recorders follow the same law (parity split).
        let (even, odd): (Vec<u64>, Vec<u64>) = samples.iter().partition(|&&v| v % 2 == 0);
        stats_eq(&fsnap.tenants[0].1, &stats_of(&even, slo)).map_err(|e| format!("t0: {e}"))?;
        stats_eq(&fsnap.tenants[1].1, &stats_of(&odd, slo)).map_err(|e| format!("t1: {e}"))?;

        // Completion order is invisible.
        for (i, (f, r)) in fsnap.racks.iter().zip(&rsnap.racks).enumerate() {
            stats_eq(f, r).map_err(|e| format!("order-dependence, rack {i}: {e}"))?;
        }
        stats_eq(&fsnap.cluster, &rsnap.cluster)
            .map_err(|e| format!("order-dependence, cluster: {e}"))?;
        if fsnap.dropped != rsnap.dropped {
            return Err("order-dependent drop counter".to_string());
        }
        Ok(())
    });
}

/// Empty-recorder edge case, pinned explicitly: a hierarchy where some
/// machines never complete anything reports zeroed racks without
/// disturbing the populated ones.
#[test]
fn empty_machines_leave_clean_racks() {
    let slo = 5 * MS;
    let tenants = ["all".to_string()];
    let agg = HierarchyAgg::new(4, 2, slo, &tenants);
    let busy = WebRun {
        stats: stats_of(&[MS, 2 * MS, 10 * MS], slo),
        tenant_stats: vec![stats_of(&[MS, 2 * MS, 10 * MS], slo)],
        completed: 3,
        ..WebRun::default()
    };
    agg.absorb(0, &busy, 1.0);
    agg.absorb(2, &WebRun::default(), 1.0); // machine with an empty recorder
    let snap = agg.finish(&[3, 0, 0, 0]);
    assert_eq!(snap.racks.len(), 2);
    assert_eq!(snap.racks[0].completed(), 3);
    assert_eq!(snap.racks[0].violations(), 1, "10 ms sample violates the 5 ms SLO");
    assert_eq!(snap.racks[1].completed(), 0, "untouched rack stays empty");
    assert_eq!(snap.racks[1].summary().completed, 0, "empty summary is well-defined");
    assert_eq!(snap.cluster.completed(), 3);
    assert_eq!(snap.digests[2].completed, 0);
}

// ---------------------------------------------------------------------
// The feedback-disabled differential (acceptance criterion)
// ---------------------------------------------------------------------

/// With the balancer disabled, the hierarchical runner must reproduce
/// the flat fleet **bytes**: identical cluster recorder (exact counters
/// and every percentile), identical per-tenant recorders, and rack
/// recorders that partition the cluster exactly.
#[test]
fn feedback_disabled_reproduces_open_loop_bytes() {
    let hcfg = hier(5, BalancerCfg::default(), 0xD1F2);
    assert!(!hcfg.balancer.enabled, "default balancer must be open-loop");
    let flat = run_fleet(&hcfg.fleet, 4);
    let h = run_hier_fleet(&hcfg, 4);

    assert_eq!(h.completed, flat.completed, "completed");
    assert_eq!(h.dropped, flat.dropped, "dropped");
    assert_eq!(h.violations, flat.violations, "exact SLO violations");
    assert!(h.outcomes.is_noop(), "open loop must not invent front-end actions");
    stats_eq(&h.stats, &flat.stats).unwrap_or_else(|e| panic!("cluster recorder: {e}"));
    summary_eq(&h.tail, &flat.tail).unwrap_or_else(|e| panic!("cluster tail: {e}"));
    assert_eq!(h.tenant_stats.len(), flat.tenant_stats.len());
    for ((na, ta), (nb, tb)) in h.tenant_stats.iter().zip(&flat.tenant_stats) {
        assert_eq!(na, nb, "tenant order must be the arrival process's");
        stats_eq(ta, tb).unwrap_or_else(|e| panic!("tenant {na}: {e}"));
    }
    // Racks partition the cluster: merging the rack recorders (racks of
    // 2 over 5 machines → 3 racks) re-creates the cluster recorder.
    assert_eq!(h.n_racks(), 3);
    let mut merged = h.racks[0].clone();
    for r in &h.racks[1..] {
        merged.merge(r);
    }
    stats_eq(&merged, &h.stats).unwrap_or_else(|e| panic!("rack partition law: {e}"));
    // Per-machine digests carry the flat run's exact counters.
    for (i, (d, m)) in h.digests.iter().zip(&flat.machines).enumerate() {
        assert_eq!(d.completed, m.completed, "machine {i} digest completed");
        assert_eq!(d.dropped, m.dropped, "machine {i} digest dropped");
        assert_eq!(d.arrivals, flat.arrivals_routed[i], "machine {i} digest arrivals");
    }
}

// ---------------------------------------------------------------------
// Closed-loop determinism (acceptance criterion)
// ---------------------------------------------------------------------

/// The closed loop — retries, hedges, ejections all active — renders
/// byte-identical reports at 1 and 4 OS threads, and two 4-thread runs
/// agree (the atomic-cursor claim order differs run to run).
#[test]
fn closed_loop_byte_identical_across_threads() {
    let hcfg = hier(4, BalancerCfg::closed(), 0xC10C);
    let serial = run_hier_fleet(&hcfg, 1);
    let parallel = run_hier_fleet(&hcfg, 4);
    let again = run_hier_fleet(&hcfg, 4);
    let render = |h: &HierFleetRun| hier_report(&[("fleet", h)]).render();
    assert_eq!(render(&serial), render(&parallel), "1 vs 4 threads differ");
    assert_eq!(render(&parallel), render(&again), "two 4-thread runs differ");
    assert_eq!(serial.outcomes, parallel.outcomes, "front-end outcome counters differ");
    assert_eq!(serial.completed, parallel.completed);
    assert_eq!(serial.violations, parallel.violations);
    let digest_key = |h: &HierFleetRun| -> Vec<(u64, u64, u64, u64)> {
        h.digests.iter().map(|d| (d.arrivals, d.completed, d.timeouts, d.epochs_ejected)).collect()
    };
    assert_eq!(digest_key(&serial), digest_key(&parallel), "per-machine digests differ");
    assert!(serial.completed > 100, "closed loop served only {}", serial.completed);
}

// ---------------------------------------------------------------------
// Each feedback mechanism demonstrably fires
// ---------------------------------------------------------------------

/// A 1 ns deadline marks every completion late, so the timeout/retry
/// path must observe timeouts and issue retries (bounded by the
/// per-request budget).
#[test]
fn closed_loop_timeouts_and_retries_fire() {
    let mut b = BalancerCfg::closed();
    b.timeout = 1; // every completion exceeds 1 ns
    b.hedge_p99_mult = 0.0; // hedging off
    b.eject_factor = 1e6; // ejection effectively off
    let h = run_hier_fleet(&hier(4, b, 0x7143), 4);
    assert!(h.outcomes.timeouts_observed > 0, "no timeouts at a 1 ns deadline");
    assert!(h.outcomes.retries_issued > 0, "timeouts must trigger retries");
    assert_eq!(h.outcomes.hedges_issued, 0, "hedging was disabled");
    assert_eq!(h.outcomes.ejections, 0, "ejection was disabled");
    let digest_timeouts: u64 = h.digests.iter().map(|d| d.timeouts).sum();
    assert_eq!(
        digest_timeouts, h.outcomes.timeouts_observed,
        "per-machine timeout attribution must sum to the cluster counter"
    );
}

/// A hedge delay far inside the latency distribution makes almost every
/// request hedge-eligible from the second epoch on.
#[test]
fn closed_loop_hedging_fires() {
    let mut b = BalancerCfg::closed();
    b.hedge_p99_mult = 0.001; // delay ≈ 0.1% of the observed p99
    b.eject_factor = 1e6;
    let h = run_hier_fleet(&hier(4, b, 0x43D6), 4);
    assert!(h.outcomes.hedges_issued > 0, "no hedges at a near-zero hedge delay");
    assert_eq!(h.outcomes.ejections, 0, "ejection was disabled");
}

/// A zero ejection threshold ejects every machine with observable tail
/// mass (the balancer never empties the healthy set), and ejected
/// machines — receiving no traffic, hence showing no tail — are
/// readmitted an epoch later.
#[test]
fn closed_loop_ejection_and_readmission_fire() {
    let mut b = BalancerCfg::closed();
    b.hedge_p99_mult = 0.0;
    b.eject_factor = 0.0; // any p99 > 0 ejects (modulo the never-empty guard)
    let h = run_hier_fleet(&hier(4, b, 0xE1EC), 4);
    assert!(h.outcomes.ejections > 0, "zero threshold must eject");
    assert!(h.outcomes.readmissions > 0, "idle ejected machines must be readmitted");
    let ejected_epochs: u64 = h.digests.iter().map(|d| d.epochs_ejected).sum();
    assert!(ejected_epochs > 0, "digests must attribute the ejected epochs");
}

// ---------------------------------------------------------------------
// O(machines) memory shape + the fleetscale scenario
// ---------------------------------------------------------------------

/// A wide sweep retains scalar digests and a constant number of
/// recorders — never per-machine runs or histograms. 64 machines in
/// racks of 8 keeps suite time sane; the shape assertions are what
/// guarantee the 1000-machine case (the result type's size does not
/// grow with anything but `machines × size_of::<MachineDigest>()`).
#[test]
fn wide_sweep_holds_o_machines_counters() {
    let mut cfg = small_cfg(0x51DE);
    cfg.warmup = 40 * MS;
    cfg.measure = 80 * MS;
    cfg.mode = LoadMode::OpenProcess { process: ArrivalProcess::two_tenant(60_000.0, 0.3) };
    let fleet = FleetCfg::new(64, RouterSpec::RoundRobin, cfg);
    let mut hcfg = HierFleetCfg::new(fleet, BalancerCfg::default());
    hcfg.machines_per_rack = 8;
    hcfg.collective_steps = 32;
    let h = run_hier_fleet(&hcfg, 4);

    assert_eq!(h.digests.len(), 64, "one digest per machine");
    assert_eq!(h.n_racks(), 8, "racks of 8");
    // The only O(machines) state is the flat digest vector of scalars.
    assert!(
        std::mem::size_of::<MachineDigest>() <= 512,
        "MachineDigest grew past a scalar record: {} bytes",
        std::mem::size_of::<MachineDigest>()
    );
    // Recorder (histogram) count is O(racks + tenants), not O(machines):
    // racks + cluster + per-tenant.
    assert_eq!(h.racks.len() + 1 + h.tenant_stats.len(), 8 + 1 + 2);
    // The collective model ran over the digests.
    let c = h.collective.as_ref().expect("collective_steps > 0 must produce a summary");
    assert_eq!(c.steps, 32);
    assert!(c.makespan_us > 0.0 && c.ideal_us > 0.0);
    assert!(c.slowdown > 0.0);
    // And it is reproducible: the collective is a pure function of the
    // digests and the seed.
    let again = run_hier_fleet(&hcfg, 2);
    let c2 = again.collective.as_ref().unwrap();
    assert_eq!((c.makespan_us, c.ideal_us, c.slowdown), (c2.makespan_us, c2.ideal_us, c2.slowdown));
}

/// The fleetscale repro declares its scenario (racks of 4, open loop,
/// collective steps, AVX subset sized to the share of work) without
/// running the sweep.
#[test]
fn fleetscale_scenario_shape() {
    let cfg = avxfreq::repro::fleetscale::hier_cfg(
        RouterSpec::AvxPartition { avx_machines: 2 },
        PolicyKind::CoreSpec { avx_cores: 2 },
        8,
        50,
        true,
        7,
    );
    assert_eq!(cfg.fleet.machines, 8);
    assert_eq!(cfg.machines_per_rack, 4);
    assert_eq!(cfg.collective_steps, 50);
    assert!(!cfg.balancer.enabled, "fleetscale runs the differential-tested open loop");
    assert_eq!(cfg.fleet.router, RouterSpec::AvxPartition { avx_machines: 2 });
    assert!(matches!(cfg.fleet.cfg.policy, PolicyKind::CoreSpec { avx_cores: 2 }));
    let process = cfg.fleet.cfg.mode.process().expect("open loop");
    // Rate scales with the fleet: 8 machines at fleetvar's 500k/6 each.
    assert!((process.mean_rate() - 8.0 * 500_000.0 / 6.0).abs() < 1.0);
    cfg.validate().expect("fleetscale scenario must validate");
}
