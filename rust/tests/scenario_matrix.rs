//! Integration tests for the scenario-matrix subsystem and the NUMA
//! machine model: cross-thread determinism (the matrix acceptance
//! property), per-socket AVX confinement, and the multi-socket Fig-5
//! sweep's shape.

use avxfreq::scenario::{PolicySpec, ScenarioMatrix, TopologySpec, WorkloadSpec};
use avxfreq::sched::PolicyKind;
use avxfreq::sim::MS;
use avxfreq::testkit::{assert_prop, IntRange};
use avxfreq::workload::client::LoadMode;
use avxfreq::workload::crypto::Isa;
use avxfreq::workload::webserver::{run_webserver_machine, WebCfg};

/// A tiny matrix that still exercises both topology kinds and both
/// policy kinds: 2 × 2 × 1 × 1 = 4 cells, short windows, small machines.
fn tiny_matrix(seed: u64) -> ScenarioMatrix {
    let mut m = ScenarioMatrix::new(seed);
    m.topologies = vec![TopologySpec::multi(1, 4), TopologySpec::multi(2, 2)];
    m.policies = vec![
        PolicySpec::Unmodified,
        PolicySpec::CoreSpecNuma { avx_cores_per_socket: 1 },
    ];
    m.workloads = vec![WorkloadSpec {
        name: "small".to_string(),
        compress: true,
        page_kib: 8,
        rate_per_core: 4_000.0,
    }];
    m.isas = vec![Isa::Avx512];
    m.warmup = 100 * MS;
    m.measure = 200 * MS;
    m
}

/// The matrix acceptance property: the same seeds produce a
/// byte-identical metrics table regardless of how many OS threads
/// execute the cells (testkit property over random base seeds).
#[test]
fn prop_matrix_deterministic_across_threads() {
    let seeds = IntRange { lo: 1, hi: 1 << 40 };
    assert_prop("matrix thread determinism", 0x3A7B1C, 3, &seeds, |&seed| {
        let serial = tiny_matrix(seed).run(1).render();
        let parallel = tiny_matrix(seed).run(4).render();
        if serial != parallel {
            return Err(format!(
                "tables differ between 1 and 4 threads:\n--- serial ---\n{serial}\n--- parallel ---\n{parallel}"
            ));
        }
        let again = tiny_matrix(seed).run(4).render();
        if parallel != again {
            return Err("same seed, two 4-thread runs differ".to_string());
        }
        Ok(())
    });
}

#[test]
fn matrix_cells_complete_and_serve() {
    let result = tiny_matrix(11).run(4);
    assert_eq!(result.cells.len(), 4);
    for cell in &result.cells {
        assert!(
            cell.run.completed > 50,
            "{} only completed {}",
            cell.scenario.label(),
            cell.run.completed
        );
    }
    // The rendered table carries one row per cell plus header lines.
    let table = result.table();
    assert_eq!(table.rows.len(), 4);
}

#[test]
fn dual_socket_corespec_numa_confines_avx_per_socket() {
    // 2 sockets × 4 cores, one AVX core per socket (cores 3 and 7).
    let mut cfg = WebCfg::paper_default(
        Isa::Avx512,
        PolicyKind::CoreSpecNuma { avx_cores_per_socket: 1, sockets: 2 },
    );
    cfg.cores = 8;
    cfg.sockets = 2;
    cfg.workers = 16;
    cfg.page_bytes = 16 * 1024;
    cfg.warmup = 150 * MS;
    cfg.measure = 500 * MS;
    cfg.mode = LoadMode::Open { rate: 50_000.0 };
    let (run, m) = run_webserver_machine(&cfg);
    assert!(run.completed > 500, "completed={}", run.completed);
    for c in [0, 1, 2, 4, 5, 6] {
        assert_eq!(
            m.cores[c].perf.license_cycles[2],
            0,
            "scalar core {c} saw AVX-512 license cycles"
        );
        assert_eq!(m.cores[c].perf.license_requests, 0, "scalar core {c} requested");
    }
    let avx_requests: u64 = [3usize, 7].iter().map(|&c| m.cores[c].perf.license_requests).sum();
    assert!(avx_requests > 0, "per-socket AVX cores must carry the licensed work");
}

#[test]
fn dual_socket_throughput_scales() {
    // Equal per-core pressure: the 2×12 machine must complete roughly
    // twice the requests of the 1×12 machine (NUMA costs shave a few
    // percent, they must not halve it).
    let mut m = ScenarioMatrix::new(5);
    m.topologies = vec![TopologySpec::single_socket_paper(), TopologySpec::dual_socket_paper()];
    m.policies = vec![PolicySpec::Unmodified];
    m.workloads = vec![WorkloadSpec {
        name: "small".to_string(),
        compress: true,
        page_kib: 16,
        rate_per_core: 3_000.0,
    }];
    m.isas = vec![Isa::Sse4];
    m.warmup = 150 * MS;
    m.measure = 400 * MS;
    let result = m.run(2);
    let single = result.throughput("1x12", Isa::Sse4, "unmodified").unwrap();
    let dual = result.throughput("2x12", Isa::Sse4, "unmodified").unwrap();
    assert!(
        dual > single * 1.6,
        "dual socket must scale throughput: {dual:.0} vs {single:.0} req/s"
    );
}

#[test]
fn fig5_multisocket_matrix_shape() {
    let m = avxfreq::repro::fig5_multisocket::matrix(true, 3);
    let cells = m.cells();
    assert_eq!(cells.len(), 12, "2 topologies × 2 policies × 3 ISAs");
    assert!(cells.iter().any(|c| c.sockets == 2));
    assert!(cells.iter().any(|c| c.policy.contains("numa")));
}
