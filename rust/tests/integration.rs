//! Integration tests: whole-system simulations, the identification
//! workflow, and the repro runners (shortened configurations — the full
//! windows run via `avxfreq repro`).

use avxfreq::analysis::flamegraph::{self, Counter};
use avxfreq::analysis::static_analysis;
use avxfreq::sched::PolicyKind;
use avxfreq::sim::MS;
use avxfreq::util::stats::pct_change;
use avxfreq::workload::client::LoadMode;
use avxfreq::workload::crypto::Isa;
use avxfreq::workload::microbench::overhead_point;
use avxfreq::workload::webserver::{
    build_binaries, run_webserver, run_webserver_machine, stack_table_for, WebCfg,
};

/// Short-window version of the paper scenario (16 KiB pages so debug-mode
/// CI stays fast; the shapes are identical to the 72 KiB default).
fn quick(isa: Isa, policy: PolicyKind) -> WebCfg {
    let mut cfg = WebCfg::paper_default(isa, policy);
    cfg.cores = 6;
    cfg.workers = 12;
    cfg.page_bytes = 16 * 1024;
    cfg.warmup = 150 * MS;
    cfg.measure = 500 * MS;
    cfg.mode = LoadMode::Open { rate: 40_000.0 };
    cfg
}

#[test]
fn webserver_fig5_shape() {
    let base = run_webserver(&quick(Isa::Sse4, PolicyKind::Unmodified));
    let avx512 = run_webserver(&quick(Isa::Avx512, PolicyKind::Unmodified));
    let spec = run_webserver(&quick(Isa::Avx512, PolicyKind::CoreSpec { avx_cores: 1 }));
    let spec_base = run_webserver(&quick(Isa::Sse4, PolicyKind::CoreSpec { avx_cores: 1 }));

    let drop_unmod = pct_change(base.throughput_rps, avx512.throughput_rps);
    let drop_spec = pct_change(spec_base.throughput_rps, spec.throughput_rps);
    assert!(drop_unmod < -4.0, "AVX-512 must hurt the unmodified scheduler: {drop_unmod:.1}%");
    assert!(
        drop_spec > drop_unmod * 0.65,
        "core specialization must recover most of the drop: {drop_spec:.1}% vs {drop_unmod:.1}%"
    );
    assert!(
        spec.avg_ghz > avx512.avg_ghz,
        "frequency must improve: {} vs {}",
        spec.avg_ghz,
        avx512.avg_ghz
    );
}

#[test]
fn webserver_sse4_corespec_overhead_is_small() {
    let base = run_webserver(&quick(Isa::Sse4, PolicyKind::Unmodified));
    let spec = run_webserver(&quick(Isa::Sse4, PolicyKind::CoreSpec { avx_cores: 1 }));
    let delta = pct_change(base.throughput_rps, spec.throughput_rps);
    assert!(delta.abs() < 3.0, "SSE4 must be ~unaffected by the mechanism, got {delta:.1}%");
    assert!(spec.type_changes_per_sec > 1000.0, "annotations must fire");
}

#[test]
fn corespec_confines_licenses_to_avx_cores() {
    let (run, m) = run_webserver_machine(&quick(Isa::Avx512, PolicyKind::CoreSpec { avx_cores: 2 }));
    assert!(run.completed > 500);
    for c in 0..4 {
        assert_eq!(m.cores[c].perf.license_cycles[2], 0, "core {c} saw L2");
        assert_eq!(m.cores[c].perf.license_requests, 0, "core {c} requested a license");
    }
    let avx_requests: u64 = (4..6).map(|c| m.cores[c].perf.license_requests).sum();
    assert!(avx_requests > 0, "AVX cores must be carrying the licensed work");
}

#[test]
fn closed_loop_mode_works() {
    let mut cfg = quick(Isa::Avx2, PolicyKind::CoreSpec { avx_cores: 1 });
    cfg.mode = LoadMode::Closed { connections: 32 };
    let run = run_webserver(&cfg);
    assert!(run.completed > 500, "closed loop must sustain itself, got {}", run.completed);
    assert!(run.tail.p50_us > 0.0);
}

#[test]
fn identification_workflow_end_to_end() {
    // Static analysis finds the crypto kernels…
    let bins = build_binaries(Isa::Avx512);
    let rows = static_analysis::analyze(&bins);
    let cands = static_analysis::candidates(&rows, 0.3);
    assert!(cands.iter().any(|c| c.function.contains("ChaCha20")));
    // …the THROTTLE flame graph isolates them from memcpy-style noise…
    let mut cfg = quick(Isa::Avx512, PolicyKind::Unmodified);
    cfg.track_flame = true;
    let (_run, m) = run_webserver_machine(&cfg);
    let stacks = stack_table_for(Isa::Avx512);
    let folded = flamegraph::fold(&m.flame, &stacks, Counter::Throttle);
    assert!(!folded.is_empty(), "throttle samples must exist");
    let crypto_hit = folded.iter().any(|(s, _)| s.contains("ChaCha20") || s.contains("poly1305"));
    assert!(crypto_hit, "crypto must appear in the throttle graph: {folded:?}");
    // …and memcpy (static-analysis false positive) never throttles.
    assert!(!folded.iter().any(|(s, _)| s.contains("memcpy")));
}

#[test]
fn microbench_overhead_sane() {
    let p = overhead_point(250_000);
    assert!(p.type_changes_per_sec > 100_000.0);
    assert!(p.overhead_pct > 0.0 && p.overhead_pct < 10.0, "overhead {}%", p.overhead_pct);
    assert!(
        (150.0..1500.0).contains(&p.ns_per_switch_pair),
        "per-pair cost {} ns",
        p.ns_per_switch_pair
    );
}

#[test]
fn repro_fast_runners_produce_tables() {
    for id in ["fig1", "fig3"] {
        let r = avxfreq::repro::run(id, true, 1).expect(id);
        assert!(!r.tables.is_empty());
        assert!(!r.tables[0].rows.is_empty(), "{id} produced no rows");
    }
}

#[test]
fn fault_migrate_webserver_confines_avx() {
    let mut cfg = quick(Isa::Avx512, PolicyKind::CoreSpec { avx_cores: 2 });
    cfg.annotate = false;
    cfg.fault_migrate = true;
    let (run, m) = run_webserver_machine(&cfg);
    assert!(run.completed > 200, "FM server must still serve: {}", run.completed);
    for c in 0..4 {
        assert_eq!(m.cores[c].perf.license_cycles[2], 0, "core {c} saw L2 under FM");
    }
    assert!(m.fm_faults > 0);
}

#[test]
fn adaptive_allocation_converges() {
    // Over-provisioned start (3 of 6 cores AVX): the §4.3 controller must
    // shrink to the demand-derived size and not oscillate.
    let mut cfg = quick(Isa::Avx512, PolicyKind::CoreSpec { avx_cores: 3 });
    cfg.adaptive = Some(avxfreq::sched::adaptive::AdaptiveParams {
        interval: 30 * MS,
        ..Default::default()
    });
    cfg.measure = 800 * MS;
    let run = run_webserver(&cfg);
    assert!(run.final_avx_cores < 3, "should shrink, final={}", run.final_avx_cores);
    assert!(run.adaptive_changes >= 1 && run.adaptive_changes <= 6, "{}", run.adaptive_changes);
    assert!(run.completed > 500);
}

#[test]
fn config_file_roundtrip() {
    let toml = r#"
seed = 7
[machine]
cores = 6
[server]
isa = "avx2"
compress = false
page_kib = 16
workers = 10
[sched]
policy = "corespec"
avx_cores = 1
adaptive = true
[load]
rate = 25000.0
warmup_s = 0.15
measure_s = 0.3
"#;
    let conf = avxfreq::util::config::Config::parse(toml).unwrap();
    let cfg = WebCfg::from_config(&conf).unwrap();
    assert_eq!(cfg.cores, 6);
    assert_eq!(cfg.isa, Isa::Avx2);
    assert!(!cfg.compress);
    assert_eq!(cfg.page_bytes, 16 * 1024);
    assert_eq!(cfg.workers, 10);
    assert!(cfg.adaptive.is_some());
    assert_eq!(cfg.seed, 7);
    matches!(cfg.mode, LoadMode::Open { rate } if (rate - 25000.0).abs() < 1e-9);
    // And it runs.
    let run = run_webserver(&cfg);
    assert!(run.completed > 100);
}

#[test]
fn shipped_configs_parse() {
    for path in [
        "configs/paper_webserver.toml",
        "configs/adaptive_demo.toml",
        "configs/dual_socket.toml",
        "configs/bursty_slo.toml",
        "configs/energy.toml",
    ] {
        let conf = avxfreq::util::config::Config::load(path).unwrap_or_else(|e| panic!("{path}: {e}"));
        let cfg = WebCfg::from_config(&conf).unwrap_or_else(|e| panic!("{path}: {e}"));
        assert!(cfg.cores >= 1 && cfg.workers >= 1);
    }
    // The energy demo config selects a non-default governor.
    let conf = avxfreq::util::config::Config::load("configs/energy.toml").unwrap();
    let cfg = WebCfg::from_config(&conf).unwrap();
    assert_eq!(cfg.governor, avxfreq::cpu::GovernorSpec::SlowRamp);
    assert_eq!(cfg.power.idle_w, 1.5);
}

#[test]
fn bursty_config_builds_bursty_process() {
    let conf = avxfreq::util::config::Config::load("configs/bursty_slo.toml").unwrap();
    let cfg = WebCfg::from_config(&conf).unwrap();
    assert_eq!(cfg.slo, 5 * avxfreq::sim::MS);
    match &cfg.mode {
        LoadMode::OpenProcess { process } => {
            assert_eq!(process.label(), "bursty");
            assert!((process.mean_rate() - 55_000.0).abs() < 1.0, "{}", process.mean_rate());
        }
        other => panic!("expected a bursty open-loop process, got {other:?}"),
    }
}

#[test]
fn uncompressed_variant_prefers_avx2() {
    // Fig 2 middle group: with crypto-heavy requests AVX2 wins. Needs the
    // full-size page (crypto must dominate the per-request cost).
    let mut sse = quick(Isa::Sse4, PolicyKind::Unmodified);
    sse.compress = false;
    sse.page_bytes = 72 * 1024;
    sse.mode = LoadMode::Open { rate: 120_000.0 };
    let mut avx2 = sse.clone();
    avx2.isa = Isa::Avx2;
    let r_sse = run_webserver(&sse);
    let r_avx2 = run_webserver(&avx2);
    assert!(
        r_avx2.throughput_rps > r_sse.throughput_rps,
        "uncompressed: AVX2 {} must beat SSE4 {}",
        r_avx2.throughput_rps,
        r_sse.throughput_rps
    );
}
