//! Checkpoint-forking suite: pins the incremental matrix fast path to
//! the cold-start reference, byte for byte. Triage bands (see
//! `rust/tests/README.md`):
//!
//! 1. **Fork differential** — a [`WebSim`] forked at *any* warmup
//!    prefix point (shrinking testkit property) must finish bit-equal
//!    to a cold `run_webserver` of the same config, and the parent it
//!    was forked from must be unperturbed. Fork-of-fork included.
//! 2. **Matrix differential** — `incremental` on ≡ off ≡ the cold
//!    per-cell runner, rendered-table bytes, at any `--threads`;
//!    fleet-layer groups fall back cold; a measures-free matrix is
//!    byte-identical to its pre-measures expansion regardless of the
//!    flag.
//! 3. **Accounting** — `warmup_ns_reused` is a pure function of the
//!    matrix declaration: `(cells − groups) × warmup`, thread-count
//!    invariant, and exactly half the total warmup on the default
//!    `incremental_sweep`.
//!
//! The cold side (`run_webserver` / the `run_cold` closure in
//! `ScenarioMatrix::run`) is the byte-reference. Never "fix" a
//! divergence by changing that side — a forked/cold mismatch is a bug
//! in the fork machinery, full stop.

use avxfreq::scenario::{ArrivalSpec, PolicySpec, ScenarioMatrix, TopologySpec, WorkloadSpec};
use avxfreq::sched::PolicyKind;
use avxfreq::sim::MS;
use avxfreq::testkit::{assert_prop, IntRange};
use avxfreq::traffic::{ArrivalProcess, RecorderArena};
use avxfreq::workload::client::LoadMode;
use avxfreq::workload::crypto::Isa;
use avxfreq::workload::webserver::{run_webserver, WebCfg, WebRun, WebSim};

/// Small but real: two tenants (so the per-tenant recorder arena path
/// is exercised), core specialization, AVX-512 build.
fn fork_cfg() -> WebCfg {
    let mut c = WebCfg::paper_default(Isa::Avx512, PolicyKind::CoreSpec { avx_cores: 1 });
    c.cores = 4;
    c.workers = 8;
    c.page_bytes = 8 * 1024;
    c.warmup = 100 * MS;
    c.measure = 200 * MS;
    c.mode = LoadMode::OpenProcess { process: ArrivalProcess::two_tenant(25_000.0, 0.3) };
    c
}

/// Bit-pattern fingerprint of a run (floats via `to_bits`), same shape
/// as the perf-equivalence suite's.
fn web_fingerprint(r: &WebRun) -> Vec<u64> {
    let mut out = vec![
        r.completed,
        r.dropped,
        r.stats.violations(),
        r.throughput_rps.to_bits(),
        r.avg_ghz.to_bits(),
        r.ipc.to_bits(),
        r.insns_per_req.to_bits(),
        r.active_energy_j.to_bits(),
        r.idle_energy_j.to_bits(),
        r.tail.p50_us.to_bits(),
        r.tail.p95_us.to_bits(),
        r.tail.p99_us.to_bits(),
        r.tail.p999_us.to_bits(),
        r.tail.max_us.to_bits(),
        r.tail.slo_violation_frac.to_bits(),
    ];
    for (_, t) in &r.tenant_tails {
        out.push(t.completed);
        out.push(t.p99_us.to_bits());
        out.push(t.slo_violation_frac.to_bits());
    }
    out
}

// ---------------------------------------------------------------------
// Band 1: fork ≡ cold at any prefix point.

#[test]
fn fork_at_any_prefix_point_matches_cold_run() {
    let cfg = fork_cfg();
    let cold = web_fingerprint(&run_webserver(&cfg));
    // t = 0 (nothing simulated yet) and t = warmup (the checkpoint the
    // matrix actually forks at) are both in range; the shrinker pulls a
    // failing fork time toward 0.
    assert_prop("fork_prefix_equiv", 0x90AB, 8, &IntRange { lo: 0, hi: cfg.warmup }, |&t| {
        let mut arena = RecorderArena::new();
        let mut sim = WebSim::new(&cfg);
        sim.run_to(t);
        let forked = sim.fork(&mut arena).ok_or_else(|| "fork declined".to_string())?;
        // The fork, finishing through the arena path, matches cold…
        if web_fingerprint(&forked.finish_into_arena(&mut arena)) != cold {
            return Err(format!("fork at t={t} diverged from cold"));
        }
        // …and the parent is unperturbed by having been forked.
        if web_fingerprint(&sim.finish().0) != cold {
            return Err(format!("parent diverged from cold after fork at t={t}"));
        }
        Ok(())
    });
}

#[test]
fn fork_of_a_fork_still_matches_cold() {
    let cfg = fork_cfg();
    let cold = web_fingerprint(&run_webserver(&cfg));
    let mut arena = RecorderArena::new();
    let mut sim = WebSim::new(&cfg);
    sim.run_to(cfg.warmup / 2);
    let g1 = sim.fork(&mut arena).expect("webserver bodies are forkable");
    let g2 = g1.fork(&mut arena).expect("a fork is itself forkable");
    drop(g1);
    drop(sim);
    assert_eq!(web_fingerprint(&g2.finish_into_arena(&mut arena)), cold);
}

#[test]
fn forked_cell_can_change_its_measure_window() {
    // `set_measure` is the one per-cell knob the measures axis varies
    // after the shared warmup; a fork with a shorter window must equal
    // a cold run declared with that window from the start.
    let base = fork_cfg();
    let mut half = base.clone();
    half.measure = base.measure / 2;
    let cold_base = web_fingerprint(&run_webserver(&base));
    let cold_half = web_fingerprint(&run_webserver(&half));
    assert_ne!(cold_base, cold_half, "the window must actually matter for this config");

    let mut arena = RecorderArena::new();
    let mut sim = WebSim::new(&base);
    sim.run_warmup();
    let mut f = sim.fork(&mut arena).expect("webserver bodies are forkable");
    f.set_measure(half.measure);
    assert_eq!(web_fingerprint(&f.finish_into_arena(&mut arena)), cold_half);
    assert_eq!(web_fingerprint(&sim.finish().0), cold_base);
}

// ---------------------------------------------------------------------
// Band 2: matrix differentials.

/// 8 cells in 4 forkable groups of 2: {unmodified, corespec} ×
/// {poisson, bursty} × {100 ms, 200 ms windows}.
fn small_measures_matrix(seed: u64) -> ScenarioMatrix {
    let mut m = ScenarioMatrix::new(seed);
    m.topologies = vec![TopologySpec::multi(1, 4)];
    m.policies = vec![PolicySpec::Unmodified, PolicySpec::CoreSpec { avx_cores: 1 }];
    m.workloads = vec![WorkloadSpec {
        name: "small".to_string(),
        compress: true,
        page_kib: 8,
        rate_per_core: 4_000.0,
    }];
    m.isas = vec![Isa::Avx512];
    m.arrivals = vec![ArrivalSpec::Poisson, ArrivalSpec::bursty_default()];
    m.warmup = 80 * MS;
    m.measure = 200 * MS;
    m.measures = vec![100 * MS, 200 * MS];
    m
}

#[test]
fn incremental_on_and_off_render_byte_identically() {
    let run = |incremental: bool| {
        let mut m = small_measures_matrix(0x1BCD);
        m.incremental = incremental;
        let r = m.run(2);
        (r.render(), r.render_tail(), r.warmup_ns_reused)
    };
    let (tbl_on, tail_on, reused_on) = run(true);
    let (tbl_off, tail_off, reused_off) = run(false);
    assert_eq!(tbl_on, tbl_off, "matrix table bytes differ across the incremental flag");
    assert_eq!(tail_on, tail_off, "tail table bytes differ across the incremental flag");
    // Accounting: one warmup re-simulated per group (the last cell
    // consumes the checkpoint), the rest reused.
    let m = small_measures_matrix(0x1BCD);
    let groups = (m.len() / m.warmup_group_size()) as u64;
    assert_eq!(reused_on, (m.len() as u64 - groups) * m.warmup);
    assert_eq!(reused_off, 0);
}

#[test]
fn incremental_matrix_bytes_are_thread_count_invariant() {
    let run = |threads: usize| {
        let r = small_measures_matrix(0x7EAD).run(threads);
        (r.render(), r.render_tail(), r.warmup_ns_reused)
    };
    assert_eq!(run(1), run(4), "forked matrix must be byte-identical at any --threads");
}

#[test]
fn measures_free_matrix_ignores_the_incremental_flag() {
    // The pre-PR shape: no measures axis → group size 1 → nothing to
    // fork. The flag must be inert in both bytes and accounting, which
    // is what makes default-on safe for every existing caller.
    let run = |incremental: bool| {
        let mut m = small_measures_matrix(0x0FF1);
        m.measures = Vec::new();
        m.incremental = incremental;
        let r = m.run(2);
        (r.render(), r.render_tail(), r.warmup_ns_reused)
    };
    let on = run(true);
    let off = run(false);
    assert_eq!(on, off);
    assert_eq!(on.2, 0, "group size 1 must never fork");
}

#[test]
fn fleet_groups_fall_back_to_the_cold_runner() {
    let run = |incremental: bool| {
        let mut m = small_measures_matrix(0xF1EE);
        m.policies.truncate(1);
        m.arrivals.truncate(1);
        m.fleet_sizes = vec![2];
        m.incremental = incremental;
        let r = m.run(2);
        (r.render(), r.render_tail(), r.render_fleet(), r.warmup_ns_reused)
    };
    let on = run(true);
    assert_eq!(on, run(false));
    assert_eq!(on.3, 0, "fleet-layer cells must not fork (cold fallback)");
}

// ---------------------------------------------------------------------
// Band 3: default-sweep accounting.

#[test]
fn default_incremental_sweep_skips_half_the_warmup() {
    let m = ScenarioMatrix::incremental_sweep(true, 0x5EED);
    let total: u64 = m.cells().iter().map(|c| c.cfg.warmup).sum();
    let r = m.run(4);
    assert!(r.warmup_ns_reused > 0);
    assert_eq!(
        r.warmup_ns_reused * 2,
        total,
        "the 2-window sweep must reuse exactly half its simulated warmup"
    );
}
