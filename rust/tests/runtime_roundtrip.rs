//! Three-layer integration: the AOT artifacts (Pallas kernel + JAX model,
//! lowered to HLO text) executed through the PJRT runtime must agree with
//! the independent Rust AEAD implementation, and the example server must
//! serve authenticated records over TCP.
//!
//! These tests skip (with a notice) when `artifacts/` has not been built;
//! run `make artifacts` first for full coverage.

use avxfreq::runtime::aead;
use avxfreq::runtime::executor::{CryptoExecutor, Width};
use avxfreq::runtime::server::{self, ServeStats};
use std::sync::Arc;

/// `Ok(dir)` when the AOT artifacts are present, `Err(dir)` with the
/// checked location otherwise. SKIP notices must name the directory —
/// `ci.sh` greps for it so a silent mis-skip (wrong env var, moved
/// artifacts) fails the build instead of shrinking coverage.
fn artifacts_dir() -> Result<String, String> {
    let dir = std::env::var("AVXFREQ_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if std::path::Path::new(&dir).join("manifest.txt").exists() {
        Ok(dir)
    } else {
        Err(dir)
    }
}

fn skip_notice(dir: &str) {
    eprintln!(
        "SKIP: artifacts directory `{dir}` missing or without manifest.txt — \
         run `make artifacts` (or set AVXFREQ_ARTIFACTS)"
    );
}

/// One executor (compiling the three HLO modules takes ~30 s each on the
/// CPU backend), shared across the checks below.
#[test]
fn pjrt_matches_rust_reference_and_authenticates() {
    let dir = match artifacts_dir() {
        Ok(dir) => dir,
        Err(dir) => return skip_notice(&dir),
    };
    let ex = CryptoExecutor::load(&dir).expect("load+compile artifacts");

    // (a) all widths agree with the independent Rust implementation.
    let mut key = [0u32; 8];
    for (i, k) in key.iter_mut().enumerate() {
        *k = 0x9E3779B9u32.wrapping_mul(i as u32 + 1);
    }
    for trial in 0..2u32 {
        let nonce = [trial, 0xFACE, 0x1234];
        let msg: Vec<u32> =
            (0..ex.record_words as u32).map(|i| i.wrapping_mul(2654435761).rotate_left(trial)).collect();
        let (want_ct, want_tag) = aead::seal_record(&key, &nonce, &msg);
        for w in Width::all() {
            let got = ex.seal(w, &key, &nonce, &msg).expect("seal");
            assert_eq!(got.ct_words, want_ct, "{w:?} trial {trial}: ciphertext");
            assert_eq!(got.tag, want_tag, "{w:?} trial {trial}: tag");
        }
    }

    // (b) PJRT output opens under the Rust AEAD and rejects tampering.
    let key2 = [7u32; 8];
    let nonce2 = [1u32, 2, 3];
    let msg2: Vec<u32> = (0..ex.record_words as u32).collect();
    let sealed = ex.seal(Width::W8, &key2, &nonce2, &msg2).unwrap();
    let opened = aead::open_record(&key2, &nonce2, &sealed.ct_words, &sealed.tag)
        .expect("authentic record must open");
    assert_eq!(opened, msg2);
    let mut bad_tag = sealed.tag;
    bad_tag[0] ^= 1;
    assert!(aead::open_record(&key2, &nonce2, &sealed.ct_words, &bad_tag).is_none());

    // (c) byte-stream chunking round-trips.
    let payload: Vec<u8> = (0..20_000u32).map(|i| (i % 251) as u8).collect();
    let (records, len) = ex.seal_bytes(Width::W16, &key2, &nonce2, &payload).unwrap();
    assert_eq!(len, payload.len());
    assert_eq!(records.len(), 2);
    let mut plain = Vec::new();
    for (i, r) in records.iter().enumerate() {
        let n = [nonce2[0] + i as u32, nonce2[1], nonce2[2]];
        let pt = aead::open_record(&key2, &n, &r.ct_words, &r.tag).expect("verify");
        plain.extend_from_slice(&aead::words_to_bytes(&pt));
    }
    assert_eq!(&plain[..len], &payload[..]);
}

#[test]
fn server_roundtrip_over_tcp() {
    let dir = match artifacts_dir() {
        Ok(dir) => dir,
        Err(dir) => return skip_notice(&dir),
    };
    let n = 3u64;
    let stats = Arc::new(ServeStats::default());
    let (tx, rx) = std::sync::mpsc::channel();
    let stats2 = stats.clone();
    let handle = std::thread::spawn(move || {
        server::serve_with_port_callback(&dir, 0, Width::W16, 1, true, n, stats2, move |p| {
            let _ = tx.send(p);
        })
    });
    let port = rx.recv_timeout(std::time::Duration::from_secs(120)).expect("server bind");
    let addr = format!("127.0.0.1:{port}");
    let page_bytes = 40_000u32;
    let expected = server::compress(&server::synth_page(page_bytes as usize));
    for _ in 0..n {
        let body = server::fetch(&addr, page_bytes).expect("fetch+verify");
        assert_eq!(body, expected, "decrypted payload must match the compressed page");
    }
    handle.join().unwrap().unwrap();
    assert_eq!(stats.requests.load(std::sync::atomic::Ordering::Relaxed), n);
    assert!(stats.records.load(std::sync::atomic::Ordering::Relaxed) >= n);
}
