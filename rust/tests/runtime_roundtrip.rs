//! Three-layer integration: the AOT artifacts (Pallas kernel + JAX model,
//! lowered to HLO text) executed through the PJRT runtime must agree with
//! the independent Rust AEAD implementation, and the example server must
//! serve authenticated records over TCP.
//!
//! These tests skip (with a notice) when `artifacts/` has not been built;
//! run `make artifacts` first for full coverage.

use avxfreq::runtime::aead;
use avxfreq::runtime::executor::{probe_backend, CryptoExecutor, Width};
use avxfreq::runtime::server::{self, ServeStats};
use std::fmt::Write as _;
use std::sync::Arc;

/// `Ok(dir)` when the AOT artifacts are present, `Err(dir)` with the
/// checked location otherwise. SKIP notices must name the directory —
/// `ci.sh` greps for it so a silent mis-skip (wrong env var, moved
/// artifacts) fails the build instead of shrinking coverage.
fn artifacts_dir() -> Result<String, String> {
    let dir = std::env::var("AVXFREQ_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if std::path::Path::new(&dir).join("manifest.txt").exists() {
        Ok(dir)
    } else {
        Err(dir)
    }
}

/// The full SKIP notice, one fact per line. Every line carries the
/// literal `SKIP: artifacts directory` prefix because `ci.sh` checks
/// each output line containing "SKIP" for that phrase; the body names
/// the expected artifact per ISA and the PJRT backend probe verdict so
/// a skip is diagnosable from the CI log alone.
fn skip_notice_text(dir: &str) -> String {
    let mut s = String::new();
    let _ = writeln!(
        s,
        "SKIP: artifacts directory `{dir}` missing or without manifest.txt — \
         run `make artifacts` (or set AVXFREQ_ARTIFACTS)"
    );
    for w in Width::all() {
        let _ = writeln!(
            s,
            "SKIP: artifacts directory `{dir}` would need chacha_w{}.hlo.txt \
             ({}-lane batch standing in for {})",
            w.lanes(),
            w.lanes(),
            w.isa_name(),
        );
    }
    let verdict = match probe_backend() {
        Ok(platform) => format!("available ({platform})"),
        Err(reason) => format!("unavailable — {reason}"),
    };
    let _ = writeln!(s, "SKIP: artifacts directory `{dir}` aside, the PJRT backend is {verdict}");
    s
}

fn skip_notice(dir: &str) {
    eprint!("{}", skip_notice_text(dir));
}

/// Pins the notice format the CI guard depends on: every line must
/// carry the `SKIP: artifacts directory` phrase (ci.sh fails any SKIP
/// line without it), and the body must name each per-ISA artifact and
/// the backend probe verdict.
#[test]
fn skip_notice_names_directory_artifacts_and_backend_on_every_line() {
    let text = skip_notice_text("some/dir");
    assert_eq!(text.lines().count(), 2 + Width::all().len(), "one line per fact:\n{text}");
    for line in text.lines() {
        assert!(
            line.starts_with("SKIP: artifacts directory `some/dir`"),
            "line would trip the ci.sh grep contract: {line}"
        );
    }
    for w in Width::all() {
        let artifact = format!("chacha_w{}.hlo.txt", w.lanes());
        assert!(text.contains(&artifact), "missing expected artifact {artifact}:\n{text}");
        assert!(text.contains(w.isa_name()), "missing ISA {}:\n{text}", w.isa_name());
    }
    assert!(text.contains("the PJRT backend is"), "missing backend probe verdict:\n{text}");
}

/// One executor (compiling the three HLO modules takes ~30 s each on the
/// CPU backend), shared across the checks below.
#[test]
fn pjrt_matches_rust_reference_and_authenticates() {
    let dir = match artifacts_dir() {
        Ok(dir) => dir,
        Err(dir) => return skip_notice(&dir),
    };
    let ex = CryptoExecutor::load(&dir).expect("load+compile artifacts");

    // (a) all widths agree with the independent Rust implementation.
    let mut key = [0u32; 8];
    for (i, k) in key.iter_mut().enumerate() {
        *k = 0x9E3779B9u32.wrapping_mul(i as u32 + 1);
    }
    for trial in 0..2u32 {
        let nonce = [trial, 0xFACE, 0x1234];
        let msg: Vec<u32> =
            (0..ex.record_words as u32).map(|i| i.wrapping_mul(2654435761).rotate_left(trial)).collect();
        let (want_ct, want_tag) = aead::seal_record(&key, &nonce, &msg);
        for w in Width::all() {
            let got = ex.seal(w, &key, &nonce, &msg).expect("seal");
            assert_eq!(got.ct_words, want_ct, "{w:?} trial {trial}: ciphertext");
            assert_eq!(got.tag, want_tag, "{w:?} trial {trial}: tag");
        }
    }

    // (b) PJRT output opens under the Rust AEAD and rejects tampering.
    let key2 = [7u32; 8];
    let nonce2 = [1u32, 2, 3];
    let msg2: Vec<u32> = (0..ex.record_words as u32).collect();
    let sealed = ex.seal(Width::W8, &key2, &nonce2, &msg2).unwrap();
    let opened = aead::open_record(&key2, &nonce2, &sealed.ct_words, &sealed.tag)
        .expect("authentic record must open");
    assert_eq!(opened, msg2);
    let mut bad_tag = sealed.tag;
    bad_tag[0] ^= 1;
    assert!(aead::open_record(&key2, &nonce2, &sealed.ct_words, &bad_tag).is_none());

    // (c) byte-stream chunking round-trips.
    let payload: Vec<u8> = (0..20_000u32).map(|i| (i % 251) as u8).collect();
    let (records, len) = ex.seal_bytes(Width::W16, &key2, &nonce2, &payload).unwrap();
    assert_eq!(len, payload.len());
    assert_eq!(records.len(), 2);
    let mut plain = Vec::new();
    for (i, r) in records.iter().enumerate() {
        let n = [nonce2[0] + i as u32, nonce2[1], nonce2[2]];
        let pt = aead::open_record(&key2, &n, &r.ct_words, &r.tag).expect("verify");
        plain.extend_from_slice(&aead::words_to_bytes(&pt));
    }
    assert_eq!(&plain[..len], &payload[..]);
}

#[test]
fn server_roundtrip_over_tcp() {
    let dir = match artifacts_dir() {
        Ok(dir) => dir,
        Err(dir) => return skip_notice(&dir),
    };
    let n = 3u64;
    let stats = Arc::new(ServeStats::default());
    let (tx, rx) = std::sync::mpsc::channel();
    let stats2 = stats.clone();
    let handle = std::thread::spawn(move || {
        server::serve_with_port_callback(&dir, 0, Width::W16, 1, true, n, stats2, move |p| {
            let _ = tx.send(p);
        })
    });
    let port = rx.recv_timeout(std::time::Duration::from_secs(120)).expect("server bind");
    let addr = format!("127.0.0.1:{port}");
    let page_bytes = 40_000u32;
    let expected =
        server::compress(&server::synth_page(page_bytes as usize)).expect("deflate");
    for _ in 0..n {
        let body = server::fetch(&addr, page_bytes).expect("fetch+verify");
        assert_eq!(body, expected, "decrypted payload must match the compressed page");
    }
    handle.join().unwrap().unwrap();
    assert_eq!(stats.requests.load(std::sync::atomic::Ordering::Relaxed), n);
    assert!(stats.records.load(std::sync::atomic::Ordering::Relaxed) >= n);
}
