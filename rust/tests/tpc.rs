//! Thread-per-core executor suite, in four bands:
//!
//! 1. **Properties** (shrinking traces via `testkit`): every job lands
//!    inside its placement policy's allowed core set; granted budgets
//!    never sum past the quantum; the `home-core` waker always requeues
//!    to the home core; `avx-steer-lazy` migrates at most once per task
//!    per AVX phase.
//! 2. **Differentials**: `LoadMode::Executor` under `home-core` on one
//!    worker is byte-identical to the shared-queue open-loop server;
//!    a matrix with the executor axis left defaulted is byte-identical
//!    to one with `executors = [Kernel]` spelled out (the pre-PR axes
//!    are untouched); `run_tpc`, the tpc sweep, and the `runtimespec`
//!    matrix are byte-identical at 1 and 4 OS threads.
//! 3. **Behavior**: on the bursty multi-tenant mix, `avx-steer` reduces
//!    p99 vs `home-core` (the paper's §5 claim restated one layer up),
//!    and `avx-steer-lazy` actually migrates.
//! 4. **Goldens**: `tpc_report` and the `runtimespec` table render
//!    byte-identically to checked-in snapshots driven by synthetic rows
//!    (`UPDATE_GOLDEN=1 cargo test --test tpc` to regenerate).
//!
//! Triage note: the differentials compare the *executor* against the
//! pre-existing shared-queue server. If one fails, the executor side is
//! the suspect — do not "fix" the reference implementation to match.

use avxfreq::cpu::GovernorSpec;
use avxfreq::repro::runtimespec;
use avxfreq::scenario::{
    ArrivalSpec, ExecutorSpec, PolicySpec, ScenarioMatrix, TopologySpec, WorkloadSpec,
};
use avxfreq::sched::PolicyKind;
use avxfreq::sim::MS;
use avxfreq::testkit::{assert_prop, IntRange, VecOf};
use avxfreq::tpc::{
    all_placements, grant_budgets, run_tpc, tpc_report, wake_core, PlacementSpec, TpcParams,
    TpcRow, TpcRuntime,
};
use avxfreq::traffic::ArrivalProcess;
use avxfreq::workload::client::LoadMode;
use avxfreq::workload::crypto::Isa;
use avxfreq::workload::webserver::{run_webserver, WebCfg};

fn trace_strategy() -> VecOf<IntRange> {
    VecOf { elem: IntRange { lo: 0, hi: u64::MAX / 2 }, max_len: 300 }
}

// ---------------------------------------------------------------------------
// Band 1: properties.
// ---------------------------------------------------------------------------

/// Every spawn, wake, and lazy migration keeps the job inside the core
/// set its placement policy allows — including the degenerate subsets
/// (`avx_cores` = 0 or ≥ n) that fall back to all cores.
#[test]
fn prop_no_job_lands_outside_its_allowed_set() {
    let specs = [
        PlacementSpec::HomeCore,
        PlacementSpec::AvxSteer { avx_cores: 2 },
        PlacementSpec::AvxSteer { avx_cores: 0 },
        PlacementSpec::AvxSteer { avx_cores: 9 },
        PlacementSpec::AvxSteerLazy { avx_cores: 2 },
    ];
    assert_prop("allowed-set confinement", 0x7C01, 60, &trace_strategy(), |ops| {
        let n = 6;
        for &spec in &specs {
            let mut rt: TpcRuntime<u64> = TpcRuntime::new(spec, n, u64::MAX, &[]);
            for &x in ops {
                let core = (x >> 3) as usize % n;
                match x % 3 {
                    0 => {
                        let marked = (x >> 2) & 1 == 1;
                        let at = rt.place(marked, x);
                        let allowed = spec.allowed_cores(marked, n);
                        if !allowed.contains(&at) {
                            return Err(format!(
                                "{spec:?}: spawned marked={marked} onto {at}, allowed {allowed:?}"
                            ));
                        }
                    }
                    1 => {
                        if let Some(job) = rt.pop(core) {
                            let marked = job.marked;
                            let woken = rt.requeue_wake(job);
                            let allowed = spec.allowed_cores(marked, n);
                            if !allowed.contains(&woken) {
                                return Err(format!(
                                    "{spec:?}: woke marked={marked} onto {woken}, allowed {allowed:?}"
                                ));
                            }
                        }
                    }
                    _ => {
                        if let Some(job) = rt.pop(core) {
                            match rt.lazy_target(core) {
                                Some(t) => {
                                    if !spec.is_avx_core(t, n) {
                                        return Err(format!(
                                            "{spec:?}: lazy target {t} outside the AVX subset"
                                        ));
                                    }
                                    rt.migrate(job, t);
                                }
                                None => {
                                    rt.requeue_wake(job);
                                }
                            }
                        }
                    }
                }
            }
        }
        Ok(())
    });
}

/// Conservation law: the budgets granted out of a quantum never sum past
/// it — for arbitrary share vectors (including zeros), both through
/// `grant_budgets` directly and through `TpcRuntime::new`'s
/// repeat-last-share expansion.
#[test]
fn prop_granted_budgets_never_exceed_the_quantum() {
    assert_prop("Σ budgets ≤ quantum", 0x7C02, 300, &trace_strategy(), |v| {
        let Some((&quantum, shares)) = v.split_first() else { return Ok(()) };
        let budgets = grant_budgets(quantum, shares);
        if budgets.len() != shares.len() {
            return Err(format!("{} budgets for {} shares", budgets.len(), shares.len()));
        }
        let sum: u128 = budgets.iter().map(|&b| b as u128).sum();
        if sum > quantum as u128 {
            return Err(format!("Σ budgets {sum} > quantum {quantum} for shares {shares:?}"));
        }
        let n = shares.len().clamp(1, 8);
        let rt: TpcRuntime<u8> = TpcRuntime::new(PlacementSpec::HomeCore, n, quantum, shares);
        let rt_sum: u128 = (0..n).map(|c| rt.budget(c) as u128).sum();
        if rt_sum > quantum as u128 {
            return Err(format!(
                "runtime Σ budgets {rt_sum} > quantum {quantum} for shares {shares:?}"
            ));
        }
        Ok(())
    });
}

/// Under `home-core` (and `avx-steer-lazy`, which moves tasks only via
/// explicit migration) a wake always requeues to the job's home core —
/// checked both on the pure waker function and through the runtime.
#[test]
fn prop_home_core_wake_always_returns_home() {
    assert_prop("home-core wake ≡ home", 0x7C03, 120, &trace_strategy(), |ops| {
        for &x in ops {
            let n = (x as usize % 16) + 1;
            let home = (x >> 8) as usize % n;
            let marked = (x >> 4) & 1 == 1;
            for spec in [PlacementSpec::HomeCore, PlacementSpec::AvxSteerLazy { avx_cores: 2 }] {
                let woken = wake_core(&spec, marked, home, n);
                if woken != home {
                    return Err(format!("{spec:?}: wake sent home={home} to {woken} (n={n})"));
                }
            }
            let mut rt: TpcRuntime<u64> =
                TpcRuntime::new(PlacementSpec::HomeCore, n, u64::MAX, &[]);
            let at = rt.place(marked, x);
            let job = rt.pop(at).expect("just placed");
            let woken = rt.requeue_wake(job);
            if woken != at {
                return Err(format!("runtime requeued home={at} to {woken} (n={n})"));
            }
        }
        Ok(())
    });
}

/// `avx-steer-lazy` migrates a task at most once per AVX phase: the
/// executor's `in_avx_phase` guard consults the runtime only on the
/// first `with_avx()` of a phase, and once inside the subset
/// `lazy_target` refuses to fire again.
#[test]
fn prop_lazy_migrates_at_most_once_per_avx_phase() {
    assert_prop("lazy ≤ 1 migration per phase", 0x7C04, 80, &trace_strategy(), |ops| {
        let n = 6;
        let spec = PlacementSpec::AvxSteerLazy { avx_cores: 2 };
        let mut rt: TpcRuntime<u64> = TpcRuntime::new(spec, n, u64::MAX, &[]);
        let mut home = rt.place(true, 0);
        let mut job = rt.pop(home).expect("just placed");
        let mut migrations_this_phase = 0u64;
        for &x in ops {
            if x & 1 == 1 {
                // `with_avx()` — the ExecutorTask guard: only the first
                // one of a phase may consult the runtime.
                if !job.in_avx_phase {
                    job.in_avx_phase = true;
                    if let Some(t) = rt.lazy_target(home) {
                        if !spec.is_avx_core(t, n) {
                            return Err(format!("lazy target {t} outside the AVX subset"));
                        }
                        rt.migrate(job, t);
                        home = t;
                        job = rt.pop(home).expect("just migrated");
                        migrations_this_phase += 1;
                        if migrations_this_phase > 1 {
                            return Err("second migration within one AVX phase".to_string());
                        }
                        if rt.lazy_target(home).is_some() {
                            return Err("lazy_target re-fires from inside the subset".to_string());
                        }
                    }
                }
            } else {
                // `without_avx()` closes the phase.
                job.in_avx_phase = false;
                migrations_this_phase = 0;
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Band 2: differentials.
// ---------------------------------------------------------------------------

fn equiv_cfg(mode: LoadMode) -> WebCfg {
    let mut cfg = WebCfg::paper_default(Isa::Avx512, PolicyKind::Unmodified);
    cfg.cores = 4;
    cfg.workers = 1; // single worker: executor queue 0 ≡ the shared queue
    cfg.page_bytes = 8 * 1024;
    cfg.warmup = 150 * MS;
    cfg.measure = 300 * MS;
    cfg.mode = mode;
    cfg
}

/// The crown differential: serving through the executor under
/// `home-core` with one worker and preemption off is byte-for-byte the
/// shared-queue open-loop server — same completions, same tails,
/// bit-equal floats and energy. (If this fails, suspect the executor
/// path: the shared-queue server is the frozen reference.)
#[test]
fn executor_home_core_single_worker_matches_the_shared_queue_server() {
    let process = ArrivalProcess::two_tenant(6_000.0, 0.3);
    let base = run_webserver(&equiv_cfg(LoadMode::OpenProcess { process: process.clone() }));
    let exec = run_webserver(&equiv_cfg(LoadMode::Executor {
        process,
        tpc: TpcParams::default(),
    }));
    assert!(base.completed > 1_000, "baseline only served {}", base.completed);
    assert_eq!(exec.completed, base.completed);
    assert_eq!(exec.dropped, base.dropped);
    assert_eq!(exec.stats.violations(), base.stats.violations());
    assert_eq!(exec.throughput_rps.to_bits(), base.throughput_rps.to_bits());
    assert_eq!(exec.avg_ghz.to_bits(), base.avg_ghz.to_bits());
    assert_eq!(exec.ipc.to_bits(), base.ipc.to_bits());
    assert_eq!(exec.active_energy_j.to_bits(), base.active_energy_j.to_bits());
    assert_eq!(exec.idle_energy_j.to_bits(), base.idle_energy_j.to_bits());
    assert_eq!(exec.tail.p50_us.to_bits(), base.tail.p50_us.to_bits());
    assert_eq!(exec.tail.p99_us.to_bits(), base.tail.p99_us.to_bits());
    assert_eq!(exec.tail.max_us.to_bits(), base.tail.max_us.to_bits());
    // home-core with preemption off neither steers, migrates, nor yields.
    assert_eq!(exec.runtime_steered, 0);
    assert_eq!(exec.runtime_migrations, 0);
    assert_eq!(exec.runtime_preemptions, 0);
}

fn tiny_kernel_matrix(seed: u64) -> ScenarioMatrix {
    let mut m = ScenarioMatrix::new(seed);
    m.topologies = vec![TopologySpec::multi(1, 4)];
    m.policies = vec![PolicySpec::CoreSpec { avx_cores: 1 }];
    m.workloads = vec![WorkloadSpec {
        name: "small".to_string(),
        compress: true,
        page_kib: 8,
        rate_per_core: 4_000.0,
    }];
    m.isas = vec![Isa::Avx512];
    m.loads = vec![0.8, 1.2];
    m.arrivals = vec![ArrivalSpec::Poisson, ArrivalSpec::bursty_default()];
    m.warmup = 100 * MS;
    m.measure = 200 * MS;
    m
}

/// The new `executors` axis defaults to exactly the pre-PR behaviour: a
/// matrix that never mentions executors renders byte-identically (matrix
/// AND tail tables, bit-equal energy) to one with
/// `executors = [ExecutorSpec::Kernel]` spelled out, and no cell picks
/// up an Executor load mode or a `/tpc:` label suffix.
#[test]
fn matrix_with_default_executor_axis_is_identical_to_explicit_kernel() {
    let implicit = tiny_kernel_matrix(0x7C30);
    assert_eq!(implicit.executors, vec![ExecutorSpec::Kernel], "default executor axis");
    let mut explicit = tiny_kernel_matrix(0x7C30);
    explicit.executors = vec![ExecutorSpec::Kernel];
    assert_eq!(implicit.len(), explicit.len());

    let a = implicit.run(2);
    let b = explicit.run(2);
    assert_eq!(a.render(), b.render(), "matrix table differs");
    assert_eq!(a.render_tail(), b.render_tail(), "tail table differs");
    for (ca, cb) in a.cells.iter().zip(&b.cells) {
        assert_eq!(ca.run.energy_j().to_bits(), cb.run.energy_j().to_bits());
        assert_eq!(ca.run.completed, cb.run.completed);
        assert!(!ca.scenario.label().contains("/tpc:"), "{}", ca.scenario.label());
        assert!(
            !matches!(ca.scenario.cfg.mode, LoadMode::Executor { .. }),
            "kernel cell must not serve through the executor"
        );
        assert_eq!(ca.run.runtime_steered, 0);
    }
}

/// `run_tpc` is byte-identical at 1 and 4 OS threads — rendered report
/// and raw bits — on a configuration that exercises shares and a finite
/// quantum, so preemption determinism is covered too.
#[test]
fn run_tpc_is_deterministic_across_threads() {
    let mut cfg = WebCfg::paper_default(Isa::Avx512, PolicyKind::Unmodified);
    cfg.cores = 4;
    cfg.workers = 4; // thread-per-core
    cfg.annotate = true;
    cfg.page_bytes = 8 * 1024;
    cfg.warmup = 150 * MS;
    cfg.measure = 300 * MS;
    cfg.mode = LoadMode::OpenProcess {
        process: ArrivalSpec::bursty_mix_default().instantiate(24_000.0),
    };
    let params =
        TpcParams { placement: PlacementSpec::HomeCore, quantum: 400_000, shares: vec![2, 1] };
    let placements = all_placements(2);
    let serial = run_tpc(&cfg, &params, &placements, 1);
    let parallel = run_tpc(&cfg, &params, &placements, 4);
    assert_eq!(tpc_report(&serial).render(), tpc_report(&parallel).render());
    for (a, b) in serial.iter().zip(&parallel) {
        assert_eq!(a.placement, b.placement);
        assert_eq!(a.throughput_rps.to_bits(), b.throughput_rps.to_bits());
        assert_eq!(a.p99_us.to_bits(), b.p99_us.to_bits());
        assert_eq!(a.p999_us.to_bits(), b.p999_us.to_bits());
        assert_eq!(a.kernel_migrations_per_sec.to_bits(), b.kernel_migrations_per_sec.to_bits());
        assert_eq!(a.mj_per_req.to_bits(), b.mj_per_req.to_bits());
        assert_eq!(
            (a.steered, a.runtime_migrations, a.preemptions),
            (b.steered, b.runtime_migrations, b.preemptions)
        );
    }
    assert!(serial.iter().all(|r| r.throughput_rps > 0.0), "{serial:?}");
    // Budgets [160k, 80k, 80k, 80k] instructions sit below a request's
    // instruction count, so the cooperative-preemption path is really on
    // in this differential.
    assert!(serial.iter().any(|r| r.preemptions > 0), "preemption never fired: {serial:?}");
}

/// The `avxfreq tpc` sweep (shrunk to the 4-core test topology) is
/// byte-identical at 1 and 4 OS threads, and every placement cell
/// completes work.
#[test]
fn tpc_matrix_is_deterministic_across_threads() {
    let mut m = ScenarioMatrix::tpc_sweep(true, 0x7C20);
    m.topologies = vec![TopologySpec::multi(1, 4)];
    m.workloads[0].rate_per_core = 8_000.0;
    let serial = m.run(1);
    let parallel = m.run(4);
    assert_eq!(serial.cells.len(), 3, "one cell per placement");
    assert_eq!(serial.render(), parallel.render(), "matrix table differs");
    assert_eq!(serial.render_tail(), parallel.render_tail(), "tail table differs");
    for (a, b) in serial.cells.iter().zip(&parallel.cells) {
        assert_eq!(a.run.energy_j().to_bits(), b.run.energy_j().to_bits());
        assert_eq!(a.run.runtime_steered, b.run.runtime_steered);
        assert_eq!(a.run.runtime_migrations, b.run.runtime_migrations);
        assert_eq!(a.run.runtime_preemptions, b.run.runtime_preemptions);
    }
    for cell in &serial.cells {
        assert!(
            cell.run.completed > 50,
            "{} only completed {}",
            cell.scenario.label(),
            cell.run.completed
        );
    }
}

/// The `repro runtimespec` matrix (shrunk to one governor × one kernel
/// policy on the 4-core test topology — same code path, smaller grid)
/// renders byte-identical runtimespec and tail tables at 1 and 4 OS
/// threads.
#[test]
fn runtimespec_matrix_is_deterministic_across_threads() {
    let mut m = runtimespec::matrix(true, 0x7C21);
    m.topologies = vec![TopologySpec::multi(1, 4)];
    m.governors = vec![GovernorSpec::SlowRamp];
    m.policies = vec![PolicySpec::CoreSpec { avx_cores: 1 }];
    m.workloads[0].rate_per_core = 8_000.0;
    let serial = m.run(1);
    let parallel = m.run(4);
    assert_eq!(serial.cells.len(), 3, "one cell per placement");
    let rows_s = runtimespec::rows(&serial);
    let rows_p = runtimespec::rows(&parallel);
    assert_eq!(
        runtimespec::table(&rows_s).render(),
        runtimespec::table(&rows_p).render(),
        "runtimespec table differs"
    );
    assert_eq!(serial.render_tail(), parallel.render_tail(), "tail table differs");
    assert!(rows_s.iter().all(|r| r.throughput_rps > 0.0), "{rows_s:?}");
}

// ---------------------------------------------------------------------------
// Band 3: behavior.
// ---------------------------------------------------------------------------

/// The acceptance claim: on the bursty multi-tenant mix, runtime-level
/// `avx-steer` reduces p99 vs `home-core` under an *unmodified* kernel —
/// the paper's §5 tail result reproduced one layer up the stack — and
/// `avx-steer-lazy` actually migrates on observed AVX demand.
#[test]
fn avx_steer_improves_bursty_mix_p99_over_home_core() {
    let mut cfg = WebCfg::paper_default(Isa::Avx512, PolicyKind::Unmodified);
    cfg.cores = 6;
    cfg.workers = 6; // thread-per-core
    cfg.annotate = true; // the runtime needs the AVX marks
    cfg.page_bytes = 16 * 1024;
    cfg.warmup = 200 * MS;
    cfg.measure = 600 * MS;
    cfg.slo = 5 * MS;
    cfg.mode = LoadMode::OpenProcess {
        process: ArrivalSpec::bursty_mix_default().instantiate(24_000.0),
    };
    let rows = run_tpc(&cfg, &TpcParams::default(), &all_placements(2), 2);
    let (home, steer, lazy) = (&rows[0], &rows[1], &rows[2]);
    assert!(home.throughput_rps > 10_000.0, "home-core served {}", home.throughput_rps);
    assert!(steer.throughput_rps > 10_000.0, "avx-steer served {}", steer.throughput_rps);
    assert!(
        steer.p99_us < home.p99_us,
        "runtime steering must improve bursty p99: {} vs {} µs",
        steer.p99_us,
        home.p99_us
    );
    assert!(steer.steered > 0, "avx-steer never steered a marked future");
    assert_eq!(home.steered, 0, "home-core must not steer");
    assert_eq!(home.runtime_migrations, 0);
    assert_eq!(steer.runtime_migrations, 0, "eager steering never migrates lazily");
    assert!(lazy.runtime_migrations > 0, "avx-steer-lazy never migrated: {lazy:?}");
}

// ---------------------------------------------------------------------------
// Band 4: goldens.
// ---------------------------------------------------------------------------

fn check_golden(name: &str, actual: &str) {
    let path = format!("{}/rust/tests/golden/{name}.txt", env!("CARGO_MANIFEST_DIR"));
    if std::env::var("UPDATE_GOLDEN").is_ok() {
        std::fs::write(&path, actual).expect("write golden");
        eprintln!("updated {path}");
        return;
    }
    let expected = std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("{path}: {e}"));
    assert!(
        actual == expected,
        "{name} drifted from its snapshot ({path}).\n--- expected ---\n{expected}\n--- actual ---\n{actual}\n\
         Run with UPDATE_GOLDEN=1 if the change is intentional."
    );
}

/// Synthetic rows with fixed values pin the `tpc_report` formatting
/// contract (column set, order, precision) independently of the
/// simulator.
#[test]
fn tpc_report_matches_snapshot() {
    let rows = vec![
        TpcRow {
            placement: "home-core".to_string(),
            throughput_rps: 48_000.0,
            p99_us: 2_000.0,
            p999_us: 3_500.0,
            steered: 0,
            runtime_migrations: 0,
            preemptions: 0,
            kernel_migrations_per_sec: 0.0,
            mj_per_req: 1.25,
        },
        TpcRow {
            placement: "avx-steer(2)".to_string(),
            throughput_rps: 52_000.0,
            p99_us: 1_500.0,
            p999_us: 2_600.0,
            steered: 9_000,
            runtime_migrations: 0,
            preemptions: 12,
            kernel_migrations_per_sec: 850.5,
            mj_per_req: 1.1,
        },
        TpcRow {
            placement: "avx-steer-lazy(2)".to_string(),
            throughput_rps: 51_000.0,
            p99_us: 1_600.0,
            p999_us: 2_750.0,
            steered: 0,
            runtime_migrations: 4_200,
            preemptions: 12,
            kernel_migrations_per_sec: 850.5,
            mj_per_req: 1.125,
        },
    ];
    check_golden("tpc_report", &tpc_report(&rows).render());
}

/// Same for the `repro runtimespec` table: one row per layer combination
/// with fixed synthetic values.
#[test]
fn runtimespec_report_matches_snapshot() {
    let rows = vec![
        runtimespec::RtRow {
            placement: "home-core".to_string(),
            policy: "unmodified".to_string(),
            governor: "intel-legacy".to_string(),
            throughput_rps: 60_000.0,
            p99_us: 2_400.0,
            p999_us: 5_200.0,
            rt_migrations_per_sec: 0.0,
            k_migrations_per_sec: 0.0,
            mj_per_req: 1.5,
        },
        runtimespec::RtRow {
            placement: "avx-steer(2)".to_string(),
            policy: "unmodified".to_string(),
            governor: "slow-ramp".to_string(),
            throughput_rps: 61_000.0,
            p99_us: 1_900.0,
            p999_us: 4_100.0,
            rt_migrations_per_sec: 0.0,
            k_migrations_per_sec: 0.0,
            mj_per_req: 1.375,
        },
        runtimespec::RtRow {
            placement: "avx-steer-lazy(2)".to_string(),
            policy: "core-spec(2)".to_string(),
            governor: "dim-silicon".to_string(),
            throughput_rps: 60_500.0,
            p99_us: 2_000.0,
            p999_us: 4_400.0,
            rt_migrations_per_sec: 350.5,
            k_migrations_per_sec: 1_200.0,
            mj_per_req: 1.425,
        },
    ];
    check_golden("runtimespec_report", &runtimespec::table(&rows).render());
}
