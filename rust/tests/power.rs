//! Power/energy layer and DVFS-governor test suite.
//!
//! Four bands (see `rust/tests/README.md` for triage):
//!
//! 1. **Differential** — `reference::RefState` is a frozen, literal copy
//!    of the pre-governor license state machine. Driven with the same
//!    randomized demand traces, today's [`LicenseState`] under the
//!    default `intel-legacy` governor must reproduce it decision for
//!    decision: same license, same throttle flag, same stall, same next
//!    edge, same request/switch counters. This pins "the governor layer
//!    is a strict superset" at the source of every frequency trace
//!    (fig1/fig6 timelines, matrix tables, and fleet reports all derive
//!    their timing from this machine).
//! 2. **Governor invariants** (testkit properties, shrinking): granted
//!    frequency always within the turbo table's bounds for the core's
//!    license level; the AVX-timer hysteresis never re-raises frequency
//!    earlier than the base hold after heavy demand; energy is
//!    non-negative, monotone, and additive under merge.
//! 3. **Determinism** — matrices carrying the governor axis (including
//!    the `repro energydelay` shape, fleet cells included) render
//!    byte-identically at 1 and 4 OS threads, with bit-equal energy.
//! 4. **Goldens** — `metrics::energy_report` and the energydelay table
//!    pinned on synthetic values (`UPDATE_GOLDEN=1` to regenerate).

use avxfreq::cpu::freq::{FreqParams, License, LicenseState};
use avxfreq::cpu::ipc::IpcParams;
use avxfreq::cpu::{Core, GovernorSpec, PerfCounters, TurboTable};
use avxfreq::isa::block::{Block, ClassMix, InsnClass};
use avxfreq::metrics::{energy_report, EnergyRow};
use avxfreq::repro::energydelay::{self, EdpRow};
use avxfreq::scenario::{ArrivalSpec, PolicySpec, ScenarioMatrix, TopologySpec, WorkloadSpec};
use avxfreq::sim::{Time, MS};
use avxfreq::testkit::{assert_prop, IntRange, VecOf};
use avxfreq::workload::crypto::Isa;

/// Frozen copy of the pre-governor `LicenseState` (PR 0–3 semantics,
/// `rust/src/cpu/freq.rs` before the governor hooks), with the three
/// policy parameters it read from `FreqParams` taken literally. Do NOT
/// "fix" or modernize this code: its value is being the old behaviour.
mod reference {
    use avxfreq::cpu::freq::License;
    use avxfreq::sim::Time;

    #[derive(Clone, Copy, PartialEq)]
    enum Phase {
        Stable,
        Throttled { target: License, grant_at: Time },
    }

    pub struct RefState {
        grant_latency: Time,
        hold: Time,
        switch_stall: Time,
        granted: License,
        phase: Phase,
        relax_at: Option<Time>,
        window_demand: License,
        stall_until: Time,
        pub requests: u64,
        pub switches: u64,
    }

    impl RefState {
        pub fn new(grant_latency: Time, hold: Time, switch_stall: Time) -> Self {
            RefState {
                grant_latency,
                hold,
                switch_stall,
                granted: License::L0,
                phase: Phase::Stable,
                relax_at: None,
                window_demand: License::L0,
                stall_until: 0,
                requests: 0,
                switches: 0,
            }
        }

        pub fn stall_ns(&self, now: Time) -> Time {
            self.stall_until.saturating_sub(now)
        }

        pub fn next_edge(&self) -> Option<Time> {
            match self.phase {
                Phase::Throttled { grant_at, .. } => Some(grant_at),
                Phase::Stable => self.relax_at,
            }
        }

        /// Returns (license, throttled) exactly as the old machine did.
        pub fn observe(&mut self, now: Time, demand: License) -> (License, bool) {
            if let Phase::Throttled { target, grant_at } = self.phase {
                if now >= grant_at {
                    self.granted = target;
                    self.phase = Phase::Stable;
                    self.switches += 1;
                    self.stall_until = grant_at + self.switch_stall;
                    self.relax_at = None;
                    self.window_demand = License::L0;
                }
            }
            let effective_target = match self.phase {
                Phase::Throttled { target, .. } => target.max(self.granted),
                Phase::Stable => self.granted,
            };
            if demand > effective_target {
                self.requests += 1;
                self.phase =
                    Phase::Throttled { target: demand, grant_at: now + self.grant_latency };
                self.relax_at = None;
            }
            if demand < self.granted && matches!(self.phase, Phase::Stable) {
                match self.relax_at {
                    None => {
                        self.relax_at = Some(now + self.hold);
                        self.window_demand = demand;
                    }
                    Some(deadline) => {
                        self.window_demand = self.window_demand.max(demand);
                        if now >= deadline {
                            let to = self.window_demand.max(demand);
                            if to < self.granted {
                                self.granted = to;
                                self.switches += 1;
                                self.stall_until = now + self.switch_stall;
                            }
                            self.relax_at = None;
                            self.window_demand = License::L0;
                        }
                    }
                }
            } else if demand >= self.granted {
                self.relax_at = None;
                self.window_demand = License::L0;
            }
            match self.phase {
                Phase::Throttled { .. } => (self.granted, true),
                Phase::Stable => (self.granted, false),
            }
        }
    }
}

/// Decode one trace step: a time advance (1 ns – 300 µs, so traces
/// cross the 40 µs grant latency and, cumulatively, the 2 ms hold) and
/// a demand level.
fn decode(x: u64) -> (Time, License) {
    let dt = 1 + x % 300_000;
    let demand = License::from_index(((x >> 20) % 3) as usize);
    (dt, demand)
}

fn trace_strategy() -> VecOf<IntRange> {
    VecOf { elem: IntRange { lo: 0, hi: u64::MAX / 2 }, max_len: 300 }
}

#[test]
fn intel_legacy_is_bit_identical_to_the_pre_governor_machine() {
    let base = FreqParams::default();
    assert_eq!(base.governor, GovernorSpec::IntelLegacy, "the default must be the anchor");
    assert_prop("legacy-differential", 0xD1FF, 150, &trace_strategy(), |xs| {
        let mut new = LicenseState::new(FreqParams::default());
        let p = FreqParams::default();
        let mut old = reference::RefState::new(p.grant_latency, p.hold, p.switch_stall);
        let mut now: Time = 0;
        for (i, &x) in xs.iter().enumerate() {
            let (dt, demand) = decode(x);
            let eff = new.observe(now, demand);
            let (lic, throttled) = old.observe(now, demand);
            if eff.license != lic || eff.throttled != throttled {
                return Err(format!(
                    "step {i} at t={now}: new ({:?}, {}) vs reference ({lic:?}, {throttled})",
                    eff.license, eff.throttled
                ));
            }
            if new.stall_ns(now) != old.stall_ns(now) {
                return Err(format!("step {i}: stall {} vs {}", new.stall_ns(now), old.stall_ns(now)));
            }
            if new.next_edge() != old.next_edge() {
                return Err(format!(
                    "step {i}: next_edge {:?} vs {:?}",
                    new.next_edge(),
                    old.next_edge()
                ));
            }
            now += dt;
        }
        if new.requests != old.requests || new.switches != old.switches {
            return Err(format!(
                "counters drifted: requests {} vs {}, switches {} vs {}",
                new.requests, old.requests, new.switches, old.switches
            ));
        }
        Ok(())
    });
}

#[test]
fn governor_frequency_always_within_license_bounds() {
    let turbo = TurboTable::xeon_gold_6130();
    let floor = turbo.ghz(License::L2, 16);
    let ceil = turbo.ghz(License::L0, 1);
    for gov in GovernorSpec::all() {
        assert_prop(
            &format!("freq-bounds[{}]", gov.name()),
            0xB0B0 ^ gov.name().len() as u64,
            60,
            &trace_strategy(),
            |xs| {
                let mut p = FreqParams::default();
                p.governor = gov;
                let mut st = LicenseState::new(p);
                let mut now: Time = 0;
                for &x in xs {
                    let (dt, demand) = decode(x);
                    let eff = st.observe(now, demand);
                    let active = 1 + (x % 16) as usize;
                    let ghz = turbo.ghz(eff.license, active);
                    if !(floor..=ceil).contains(&ghz) {
                        return Err(format!("ghz {ghz} outside [{floor}, {ceil}]"));
                    }
                    // The frequency must be the one the granted license
                    // allows at this active-core count — never above the
                    // license's own ceiling.
                    if ghz > turbo.ghz(eff.license, 1) {
                        return Err(format!("ghz {ghz} above the license ceiling"));
                    }
                    now += dt;
                }
                Ok(())
            },
        );
    }
}

#[test]
fn hysteresis_never_re_raises_frequency_before_the_timeout() {
    // Under every governor the hold window is at least the base 2 ms:
    // after the last observation with demand ≥ the granted license, no
    // transition to a *faster* license may occur sooner than that.
    let base_hold = FreqParams::default().hold;
    for gov in GovernorSpec::all() {
        assert_prop(
            &format!("hysteresis[{}]", gov.name()),
            0x4AEA ^ gov.name().len() as u64,
            60,
            &trace_strategy(),
            |xs| {
                let mut p = FreqParams::default();
                p.governor = gov;
                let mut st = LicenseState::new(p);
                let mut now: Time = 0;
                let mut last_heavy: Time = 0;
                for &x in xs {
                    let (dt, demand) = decode(x);
                    let before = st.granted();
                    let eff = st.observe(now, demand);
                    if eff.license < before && now < last_heavy + base_hold {
                        return Err(format!(
                            "re-raised {:?} → {:?} at t={now}, only {} ns after heavy \
                             demand (hold is {base_hold})",
                            before,
                            eff.license,
                            now - last_heavy
                        ));
                    }
                    if demand >= st.granted() {
                        last_heavy = now;
                    }
                    now += dt;
                }
                Ok(())
            },
        );
    }
}

/// Decode a block for the energy properties: mostly scalar with
/// interleaved heavy-AVX blocks.
fn decode_block(x: u64) -> Block {
    let insns = 1_000 + x % 40_000;
    if x % 4 == 0 {
        Block {
            mix: ClassMix::of(InsnClass::Avx512Heavy, insns),
            mem_ops: 0,
            branches: insns / 60,
            license_exempt: false,
        }
    } else {
        Block {
            mix: ClassMix::scalar(insns),
            mem_ops: x % 50,
            branches: insns / 30,
            license_exempt: false,
        }
    }
}

#[test]
fn energy_is_nonnegative_and_monotone_under_every_governor() {
    let turbo = TurboTable::xeon_gold_6130_no_cstates();
    for gov in GovernorSpec::all() {
        assert_prop(
            &format!("energy-monotone[{}]", gov.name()),
            0xE4E4 ^ gov.name().len() as u64,
            40,
            &trace_strategy(),
            |xs| {
                let mut p = FreqParams::default();
                p.governor = gov;
                let mut core = Core::new(0, p, IpcParams::default());
                let mut now: Time = 0;
                let mut prev = 0.0f64;
                for (i, &x) in xs.iter().enumerate() {
                    let out = if x % 7 == 6 {
                        // Idle gaps must also be charged (idle power).
                        core.idle_until(now, now + 1 + x % 100_000);
                        now += 1 + x % 100_000;
                        None
                    } else {
                        let o = core.run_block(now, &decode_block(x), x % 5, 16, &turbo);
                        now += o.ns;
                        Some(o)
                    };
                    let e = core.perf.energy_j();
                    if !(e.is_finite() && e >= prev && e >= 0.0) {
                        return Err(format!("step {i}: energy {e} after {prev} ({out:?})"));
                    }
                    prev = e;
                }
                let agree =
                    (core.perf.energy_j() - core.perf.active_energy_j - core.perf.idle_energy_j)
                        .abs();
                if agree > 1e-12 {
                    return Err(format!("energy components disagree by {agree}"));
                }
                Ok(())
            },
        );
    }
}

#[test]
fn energy_is_additive_under_merge() {
    // Split any slice stream at any point: recording the two halves
    // into separate counters and merging equals recording the whole
    // stream into one counter — the same law LatencyStats::merge obeys,
    // and what makes fleet-level Joules trustworthy.
    assert_prop("energy-merge", 0xADD0, 200, &trace_strategy(), |xs| {
        let energies: Vec<f64> = xs.iter().map(|&x| (x % 1_000_000) as f64 * 1e-6).collect();
        let cut = energies.len() / 2;
        let mut whole = PerfCounters::default();
        let mut left = PerfCounters::default();
        let mut right = PerfCounters::default();
        for (i, &e) in energies.iter().enumerate() {
            whole.record_active_energy(e);
            whole.record_idle_energy(e / 3.0);
            let half = if i < cut { &mut left } else { &mut right };
            half.record_active_energy(e);
            half.record_idle_energy(e / 3.0);
        }
        left.merge(&right);
        let scale = whole.energy_j().abs().max(1.0);
        if (left.energy_j() - whole.energy_j()).abs() / scale > 1e-12 {
            return Err(format!(
                "merge {} vs whole {}",
                left.energy_j(),
                whole.energy_j()
            ));
        }
        Ok(())
    });
}

/// Small, fast matrix shape shared by the determinism tests: 4 cores,
/// 8 KiB pages, short windows — the same shape the existing golden /
/// fleet determinism tests use. `governors: None` leaves the axis at
/// the `ScenarioMatrix::new` default (the differential anchor relies
/// on exercising that default, not restating it).
fn small_matrix(governors: Option<Vec<GovernorSpec>>) -> ScenarioMatrix {
    let mut m = ScenarioMatrix::new(0x9055);
    m.topologies = vec![TopologySpec::multi(1, 4)];
    m.policies = vec![PolicySpec::CoreSpec { avx_cores: 1 }];
    m.workloads = vec![WorkloadSpec {
        name: "small".to_string(),
        compress: true,
        page_kib: 8,
        rate_per_core: 4_000.0,
    }];
    m.isas = vec![Isa::Avx512];
    m.arrivals = vec![ArrivalSpec::Poisson];
    if let Some(governors) = governors {
        m.governors = governors;
    }
    m.warmup = 100 * MS;
    m.measure = 200 * MS;
    m
}

#[test]
fn default_matrix_is_identical_to_explicit_intel_legacy() {
    // The governor axis defaults to [IntelLegacy]; spelling it out must
    // change nothing — same cells, same bytes, same Joules. Together
    // with the state-machine differential above, this pins the whole
    // default matrix/fleet reporting path as byte-identical to pre-PR.
    // The implicit side deliberately does NOT set the governors field:
    // if the constructor default ever stopped being [IntelLegacy], this
    // test must catch it.
    let implicit = small_matrix(None);
    assert_eq!(implicit.governors, vec![GovernorSpec::IntelLegacy]);
    let explicit = small_matrix(Some(vec![GovernorSpec::IntelLegacy]));
    let a = implicit.run(2);
    let b = explicit.run(2);
    assert_eq!(a.render(), b.render());
    assert_eq!(a.render_tail(), b.render_tail());
    for (x, y) in a.cells.iter().zip(&b.cells) {
        assert_eq!(x.run.active_energy_j, y.run.active_energy_j);
        assert_eq!(x.run.idle_energy_j, y.run.idle_energy_j);
    }
}

#[test]
fn governor_matrix_deterministic_and_energy_invariant_across_threads() {
    let m = small_matrix(Some(GovernorSpec::all().to_vec()));
    let serial = m.run(1);
    let parallel = m.run(4);
    assert_eq!(serial.render(), parallel.render(), "matrix table differs across threads");
    for (a, b) in serial.cells.iter().zip(&parallel.cells) {
        // Energy is f64 but each cell's computation is single-threaded
        // and seeded, so it must be bit-equal, not merely close.
        assert_eq!(a.run.active_energy_j, b.run.active_energy_j, "cell {}", a.scenario.index);
        assert_eq!(a.run.idle_energy_j, b.run.idle_energy_j, "cell {}", a.scenario.index);
        assert!(a.run.energy_j() > 0.0);
    }
    // The governor axis must not be decorative: slow-ramp charges a
    // voltage-ramp stall on the (certain) first AVX license grant of
    // every AVX-executing core, which shifts all downstream event
    // timing — the cell's measured outputs must differ from legacy's.
    // (dim-silicon only diverges under switch churn, which this
    // steady-load cell need not exhibit; its behaviour is pinned by
    // `sched::machine::tests::governor_selectable_per_machine`.)
    let legacy = &serial.cells[0].run;
    let slow = &serial.cells[1].run;
    assert!(
        (legacy.avg_ghz - slow.avg_ghz).abs() > 1e-12
            || (legacy.energy_j() - slow.energy_j()).abs() > 1e-12
            || (legacy.tail.p99_us - slow.tail.p99_us).abs() > 1e-12,
        "slow-ramp cell is indistinguishable from legacy"
    );
}

#[test]
fn energydelay_matrix_is_deterministic_across_threads() {
    // The exact `repro energydelay` code path (governor × fleet axes,
    // EdpRow extraction, table rendering) on a shrunk shape: byte-equal
    // at 1 and 4 threads.
    let mut m = energydelay::matrix(true, 0xED01);
    m.topologies = vec![TopologySpec::multi(1, 4)];
    m.policies = vec![PolicySpec::Unmodified, PolicySpec::CoreSpec { avx_cores: 1 }];
    m.workloads = vec![WorkloadSpec {
        name: "small".to_string(),
        compress: true,
        page_kib: 8,
        rate_per_core: 4_000.0,
    }];
    m.fleet_sizes = vec![1, 2];
    m.warmup = 100 * MS;
    m.measure = 200 * MS;
    assert_eq!(m.len(), 12, "2 policies × 3 governors × 2 fleet sizes");
    let serial = m.run(1);
    let parallel = m.run(4);
    let t1 = energydelay::table(&energydelay::rows(&serial)).render();
    let t4 = energydelay::table(&energydelay::rows(&parallel)).render();
    assert_eq!(t1, t4, "energydelay table differs across threads");
    assert_eq!(serial.render_fleet(), parallel.render_fleet(), "fleet table differs");
    // Fleet rows carry summed machine energy.
    for c in serial.cells.iter().filter(|c| c.scenario.fleet > 1) {
        let f = c.fleet.as_ref().expect("fleet cell");
        let sum: f64 = f.machines.iter().map(|m| m.energy_j()).sum();
        assert!((c.run.energy_j() - sum).abs() < 1e-9, "cluster energy must sum machines");
    }
}

fn check_golden(name: &str, actual: &str) {
    let path = format!("{}/rust/tests/golden/{name}.txt", env!("CARGO_MANIFEST_DIR"));
    if std::env::var("UPDATE_GOLDEN").is_ok() {
        std::fs::write(&path, actual).expect("write golden");
        eprintln!("updated {path}");
        return;
    }
    let expected = std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("{path}: {e}"));
    assert!(
        actual == expected,
        "{name} drifted from its snapshot ({path}).\n--- expected ---\n{expected}\n--- actual ---\n{actual}\n\
         Run with UPDATE_GOLDEN=1 if the change is intentional."
    );
}

#[test]
fn energy_report_matches_snapshot() {
    let rows = vec![
        EnergyRow {
            scope: "core0".to_string(),
            governor: "intel-legacy".to_string(),
            active_j: 10.5,
            idle_j: 2.5,
            completed: 0,
            secs: 2.0,
        },
        EnergyRow {
            scope: "machine".to_string(),
            governor: "slow-ramp".to_string(),
            active_j: 100.0,
            idle_j: 25.0,
            completed: 50_000,
            secs: 2.0,
        },
        EnergyRow {
            scope: "cluster".to_string(),
            governor: "dim-silicon".to_string(),
            active_j: 400.0,
            idle_j: 100.0,
            completed: 160_000,
            secs: 2.0,
        },
    ];
    check_golden("energy_report", &energy_report(&rows).render());
}

#[test]
fn energydelay_report_matches_snapshot() {
    let rows = vec![
        EdpRow {
            scale: "machine".to_string(),
            policy: "unmodified".to_string(),
            governor: "intel-legacy".to_string(),
            throughput_rps: 48_000.0,
            p99_us: 2_000.0,
            energy_j: 120.0,
            mj_per_req: 2.5,
            req_per_j: 400.0,
        },
        EdpRow {
            scale: "fleet(4)".to_string(),
            policy: "core-spec(2)".to_string(),
            governor: "slow-ramp".to_string(),
            throughput_rps: 201_000.0,
            p99_us: 1_500.0,
            energy_j: 400.0,
            mj_per_req: 2.0,
            req_per_j: 500.0,
        },
    ];
    check_golden("energydelay_report", &energydelay::table(&rows).render());
}
