//! Hybrid-topology integration tests: golden snapshots for the two
//! hybrid tables, cross-thread byte determinism of the hybridspec
//! matrix, the all-P-hybrid ≡ homogeneous differential at matrix level,
//! and the end-to-end AVX-512/E-core confinement property.
//!
//! The snapshots are driven by *synthetic* rows/cells with fixed values
//! (exactly representable at the printed precision), so they pin the
//! formatting contract independently of the simulator. To regenerate
//! after an intentional format change:
//! `UPDATE_GOLDEN=1 cargo test --test hybrid`.

use avxfreq::cpu::{GovernorSpec, HybridSpec};
use avxfreq::fleet::{BalancerCfg, RouterSpec};
use avxfreq::metrics::hybrid_report;
use avxfreq::repro::hybridspec::{self, HsRow};
use avxfreq::scenario::{
    CellResult, ExecutorSpec, FaultSpec, PolicySpec, Scenario, ScenarioMatrix, TopologySpec,
    WorkloadSpec,
};
use avxfreq::sched::PolicyKind;
use avxfreq::sim::MS;
use avxfreq::traffic::{LatencyStats, TailSummary};
use avxfreq::workload::crypto::Isa;
use avxfreq::workload::webserver::{run_webserver_machine, WebCfg, WebRun};

fn tail(completed: u64) -> TailSummary {
    TailSummary {
        completed,
        mean_us: 250.0,
        p50_us: 250.0,
        p95_us: 1_500.0,
        p99_us: 2_000.0,
        p999_us: 3_500.0,
        max_us: 8_000.0,
        slo_us: 5_000.0,
        slo_violation_frac: 0.125,
    }
}

/// A synthetic matrix cell whose only interesting payload is
/// `domain_ghz` — everything `hybrid_report` reads is fixed here, so the
/// snapshot depends on nothing but the renderer.
fn domain_cell(
    index: usize,
    topology: &str,
    policy: &str,
    governor: GovernorSpec,
    domain_ghz: Vec<(String, f64)>,
) -> CellResult {
    let scenario = Scenario {
        index,
        topology: topology.to_string(),
        sockets: 1,
        policy: policy.to_string(),
        workload: "compressed".to_string(),
        isa: Isa::Avx512,
        load: 1.0,
        arrival: "poisson".to_string(),
        fleet: 1,
        router: RouterSpec::RoundRobin,
        governor,
        executor: ExecutorSpec::Kernel,
        balancer: BalancerCfg::default(),
        faults: FaultSpec::None,
        measure_point: None,
        seed: 7,
        cfg: WebCfg::paper_default(Isa::Avx512, PolicyKind::Unmodified),
    };
    let t = tail(48_000);
    let run = WebRun {
        cfg_name: "synthetic".to_string(),
        throughput_rps: 48_000.0,
        avg_ghz: 2.75,
        ipc: 1.5,
        insns_per_req: 1_000_000.0,
        tail: t,
        tenant_tails: vec![("all".to_string(), t)],
        stats: LatencyStats::new(5 * MS),
        tenant_stats: vec![LatencyStats::new(5 * MS)],
        dropped: 0,
        type_changes_per_sec: 9_000.0,
        migrations_per_sec: 1_200.0,
        cross_socket_migrations_per_sec: 0.0,
        runtime_steered: 0,
        runtime_migrations: 0,
        runtime_migrations_per_sec: 0.0,
        runtime_preemptions: 0,
        active_energy_j: 0.0,
        idle_energy_j: 0.0,
        throttle_ratio: 0.0625,
        license_share: [0.75, 0.125, 0.125],
        completed: t.completed,
        final_avx_cores: 2,
        adaptive_changes: 0,
        domain_ghz,
    };
    CellResult { scenario, run, fleet: None, hier: None }
}

fn check_golden(name: &str, actual: &str) {
    let path = format!("{}/rust/tests/golden/{name}.txt", env!("CARGO_MANIFEST_DIR"));
    if std::env::var("UPDATE_GOLDEN").is_ok() {
        std::fs::write(&path, actual).expect("write golden");
        eprintln!("updated {path}");
        return;
    }
    let expected = std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("{path}: {e}"));
    assert!(
        actual == expected,
        "{name} drifted from its snapshot ({path}).\n--- expected ---\n{expected}\n--- actual ---\n{actual}\n\
         Run with UPDATE_GOLDEN=1 if the change is intentional."
    );
}

/// The homogeneous middle cell carries no domain rows and must be
/// skipped entirely — the snapshot has rows only for cells 0 and 2.
#[test]
fn hybrid_report_matches_snapshot() {
    let cells = vec![
        domain_cell(
            0,
            "8P+16E",
            "class-native(8)",
            GovernorSpec::IntelLegacy,
            vec![
                ("skt0".to_string(), 3.0),
                ("mod0".to_string(), 2.5),
                ("mod1".to_string(), 2.125),
            ],
        ),
        domain_cell(1, "1x24", "unmodified", GovernorSpec::IntelLegacy, Vec::new()),
        domain_cell(
            2,
            "8P+16E",
            "unmodified",
            GovernorSpec::SlowRamp,
            vec![("skt0".to_string(), 2.75), ("mod0".to_string(), 1.875)],
        ),
    ];
    check_golden("hybrid_report", &hybrid_report(&cells).render());
}

#[test]
fn hybridspec_report_matches_snapshot() {
    let rows = vec![
        HsRow {
            topology: "8P+16E".to_string(),
            policy: "unmodified".to_string(),
            governor: "intel-legacy".to_string(),
            throughput_rps: 52_000.0,
            p99_us: 2_400.0,
            p999_us: 4_100.0,
            avg_ghz: 2.625,
            slow_domain: Some(("mod2".to_string(), 2.125)),
        },
        HsRow {
            topology: "8P+16E".to_string(),
            policy: "class-native(8)".to_string(),
            governor: "intel-legacy".to_string(),
            throughput_rps: 61_500.0,
            p99_us: 1_650.0,
            p999_us: 2_900.0,
            avg_ghz: 3.125,
            slow_domain: Some(("mod1".to_string(), 2.75)),
        },
        HsRow {
            topology: "1x24".to_string(),
            policy: "unmodified".to_string(),
            governor: "intel-legacy".to_string(),
            throughput_rps: 64_000.0,
            p99_us: 1_500.0,
            p999_us: 2_600.0,
            avg_ghz: 2.75,
            slow_domain: None,
        },
    ];
    check_golden("hybridspec_report", &hybridspec::table(&rows).render());
}

/// The determinism acceptance criterion for the new topology axis: a
/// shrunk hybridspec matrix (both machine shapes, all three policies,
/// one governor) renders byte-identical comparison, tail, AND
/// per-domain tables at 1 and 4 OS threads.
#[test]
fn hybrid_matrix_renders_identically_at_1_and_4_threads() {
    let mut m = hybridspec::matrix(true, 0x42_1207);
    m.governors = vec![GovernorSpec::IntelLegacy];
    m.warmup = 100 * MS;
    m.measure = 200 * MS;
    assert_eq!(m.len(), 6, "2 topologies × 3 policies");

    let serial = m.run(1);
    let parallel = m.run(4);
    assert_eq!(serial.render(), parallel.render(), "matrix table differs across threads");
    assert_eq!(
        serial.render_tail(),
        parallel.render_tail(),
        "tail table differs across threads"
    );
    assert_eq!(
        hybrid_report(&serial.cells).render(),
        hybrid_report(&parallel.cells).render(),
        "per-domain table differs across threads"
    );
    // Non-vacuity: the hybrid half actually produced per-domain rows.
    assert!(!hybrid_report(&serial.cells).rows.is_empty());
}

/// A hybrid spec with zero E-cores is the homogeneous machine, all the
/// way up through the matrix runner: same seeds, same schedules, same
/// rendered bytes. (The machine-level twin of this test lives in
/// `sched::machine`; this one covers the scenario/webserver plumbing.)
#[test]
fn all_p_hybrid_matrix_matches_homogeneous_bytes() {
    let mk = |all_p_hybrid: bool| {
        let mut topo = TopologySpec::multi(1, 24);
        if all_p_hybrid {
            topo.hybrid = Some(HybridSpec::new(24, 0, 0).expect("all-P spec is valid"));
        }
        let mut m = ScenarioMatrix::new(0xA11F);
        m.topologies = vec![topo];
        m.policies = vec![PolicySpec::Unmodified, PolicySpec::ClassNative { p_cores: 8 }];
        m.workloads = vec![WorkloadSpec::compressed_page()];
        m.isas = vec![Isa::Avx512];
        m.warmup = 100 * MS;
        m.measure = 200 * MS;
        m
    };
    let hybrid = mk(true).run(2);
    let homog = mk(false).run(2);
    assert_eq!(hybrid.render(), homog.render(), "matrix table differs");
    assert_eq!(hybrid.render_tail(), homog.render_tail(), "tail table differs");
    // All-P machines report no per-domain rows — on either side.
    assert!(hybrid_report(&hybrid.cells).rows.is_empty());
    assert!(hybrid_report(&homog.cells).rows.is_empty());
}

/// The capability property end-to-end: on the 8P+16E part serving the
/// AVX-512 workload, no 512-bit block ever executes on an E-core —
/// under the confined stock scheduler and under class-native alike —
/// while the E-cores still carry (scalar) work.
#[test]
fn avx512_stays_off_e_cores_end_to_end() {
    for policy in [PolicyKind::Unmodified, PolicyKind::ClassNative { p_cores: 8 }] {
        let mut cfg = WebCfg::paper_default(Isa::Avx512, policy.clone());
        cfg.cores = 24;
        cfg.workers = 48;
        cfg.hybrid = Some(HybridSpec::desktop_8p16e());
        cfg.warmup = 100 * MS;
        cfg.measure = 300 * MS;
        let (run, m) = run_webserver_machine(&cfg);
        assert!(run.completed > 0, "{policy:?}: server did no work");
        assert_eq!(
            m.e_wide512_blocks, 0,
            "{policy:?}: an AVX-512 block executed on an E-core"
        );
        // One socket + four 4-core modules, every domain reported.
        assert_eq!(run.domain_ghz.len(), 5, "{policy:?}: domain rows");
        assert!(
            run.domain_ghz.iter().any(|(d, g)| d.starts_with("mod") && *g > 0.0),
            "{policy:?}: no E-core module ever ran — confinement test is vacuous"
        );
    }
}
