//! Integration tests for the traffic engine: tail-latency SLO metrics
//! under non-Poisson arrivals, the paper's §5 claim restated as p99
//! (core specialization must keep the tail near the baseline under
//! bursty AVX-512 load), and cross-thread determinism of the traffic
//! sweep's tables.

use avxfreq::scenario::{ArrivalSpec, PolicySpec, ScenarioMatrix, TopologySpec, WorkloadSpec};
use avxfreq::sched::PolicyKind;
use avxfreq::sim::MS;
use avxfreq::traffic::ArrivalProcess;
use avxfreq::workload::client::LoadMode;
use avxfreq::workload::crypto::Isa;
use avxfreq::workload::webserver::{run_webserver, WebCfg};

/// Short-window bursty scenario on the integration-test machine shape
/// (6 cores, 16 KiB pages): mean rate below the AVX-512 capacity, bursts
/// above it, so the tail is dominated by how fast the scheduler drains
/// each burst.
fn bursty_cfg(policy: PolicyKind) -> WebCfg {
    let mut cfg = WebCfg::paper_default(Isa::Avx512, policy);
    cfg.cores = 6;
    cfg.workers = 12;
    cfg.page_bytes = 16 * 1024;
    cfg.warmup = 200 * MS;
    cfg.measure = 600 * MS;
    cfg.slo = 5 * MS;
    cfg.mode = LoadMode::OpenProcess {
        process: ArrivalProcess::Bursty {
            base_rate: 12_000.0,
            burst_rate: 55_000.0,
            on: 80 * MS,
            off: 120 * MS,
        },
    };
    cfg
}

/// Satellite acceptance: with `PolicyKind::CoreSpec` enabled, webserver
/// p99 under the bursty arrival process improves vs the unmitigated
/// baseline — the §5 claim restated as tail damage on a short window.
#[test]
fn corespec_improves_bursty_p99_over_baseline() {
    let unmod = run_webserver(&bursty_cfg(PolicyKind::Unmodified));
    let spec = run_webserver(&bursty_cfg(PolicyKind::CoreSpec { avx_cores: 2 }));
    assert!(unmod.completed > 1_000, "baseline served {}", unmod.completed);
    assert!(spec.completed > 1_000, "core-spec served {}", spec.completed);
    assert!(
        spec.tail.p99_us < unmod.tail.p99_us,
        "core specialization must improve bursty p99: {} vs {} µs",
        spec.tail.p99_us,
        unmod.tail.p99_us
    );
    // The same ordering must hold for the SLO damage (ties allowed —
    // both can be 0 at this window if the bursts fully drain).
    assert!(
        spec.tail.slo_violation_frac <= unmod.tail.slo_violation_frac,
        "SLO violations must not get worse: {} vs {}",
        spec.tail.slo_violation_frac,
        unmod.tail.slo_violation_frac
    );
}

/// p999 and max never undercut p99, and the violation fraction is exact
/// (0 ≤ f ≤ 1), on a process that actually stresses the tail.
#[test]
fn tail_metrics_are_ordered_under_bursts() {
    let run = run_webserver(&bursty_cfg(PolicyKind::CoreSpec { avx_cores: 2 }));
    let t = &run.tail;
    assert!(t.p50_us <= t.p99_us && t.p99_us <= t.p999_us && t.p999_us <= t.max_us);
    assert!((0.0..=1.0).contains(&t.slo_violation_frac));
    assert_eq!(t.completed, run.completed);
}

fn tiny_traffic_matrix(seed: u64) -> ScenarioMatrix {
    let mut m = ScenarioMatrix::new(seed);
    m.topologies = vec![TopologySpec::multi(1, 4)];
    m.policies = vec![PolicySpec::CoreSpec { avx_cores: 1 }];
    m.workloads = vec![WorkloadSpec {
        name: "small".to_string(),
        compress: true,
        page_kib: 8,
        rate_per_core: 4_000.0,
    }];
    m.isas = vec![Isa::Avx512];
    m.loads = vec![0.5, 0.9, 1.2];
    m.arrivals = vec![ArrivalSpec::Poisson, ArrivalSpec::bursty_default()];
    m.warmup = 100 * MS;
    m.measure = 200 * MS;
    m
}

/// Acceptance: the traffic sweep (≥3 loads × ≥2 arrival processes) is
/// deterministic across 1 and 4 OS threads — byte-identical matrix AND
/// tail tables — and every cell completes requests.
#[test]
fn traffic_matrix_deterministic_across_threads() {
    let m = tiny_traffic_matrix(0x7EA1);
    let serial = m.run(1);
    let parallel = m.run(4);
    assert_eq!(serial.render(), parallel.render(), "matrix table differs");
    assert_eq!(serial.render_tail(), parallel.render_tail(), "tail table differs");
    assert_eq!(serial.cells.len(), 6);
    for cell in &serial.cells {
        assert!(
            cell.run.completed > 50,
            "{} only completed {}",
            cell.scenario.label(),
            cell.run.completed
        );
    }
    // Higher offered load must not lower completed work (open loop).
    let done = |arrival: &str, load: f64| {
        serial
            .find_cell("1x4", Isa::Avx512, "core-spec(1)", arrival, load)
            .map(|c| c.run.completed)
            .expect("cell present")
    };
    assert!(done("poisson", 1.2) > done("poisson", 0.5));
}

/// The multi-tenant mix rides through the matrix: the tail table gets
/// one row per tenant and both tenants complete work.
#[test]
fn tenant_mix_cell_reports_per_tenant_rows() {
    let mut m = tiny_traffic_matrix(0x313);
    m.loads = vec![1.0];
    m.arrivals = vec![ArrivalSpec::TenantMix { avx_share: 0.3 }];
    let result = m.run(2);
    assert_eq!(result.cells.len(), 1);
    let run = &result.cells[0].run;
    assert_eq!(run.tenant_tails.len(), 2);
    assert!(run.tenant_tails.iter().all(|(_, t)| t.completed > 50));
    let table = result.tail_table();
    assert_eq!(table.rows.len(), 2, "one tail row per tenant");
    // Aggregate equals the tenant sum (every completion is attributed).
    let sum: u64 = run.tenant_tails.iter().map(|(_, t)| t.completed).sum();
    assert_eq!(run.completed, sum);
}

/// The fig5tail sweep declares the acceptance grid (≥3 loads × ≥2
/// arrivals × both schedulers × sse4+avx512) without running it.
#[test]
fn fig5tail_matrix_shape() {
    let m = avxfreq::repro::fig5tail::matrix(true, 3);
    assert!(m.loads.len() >= 3);
    assert!(m.arrivals.len() >= 2);
    let cells = m.cells();
    assert_eq!(cells.len(), 24, "2 policies × 2 ISAs × 3 loads × 2 arrivals");
    assert!(cells.iter().any(|c| c.arrival == "bursty"));
    assert!(cells.iter().any(|c| c.policy.contains("core-spec")));
    assert!(cells.iter().any(|c| c.isa == Isa::Sse4));
}
