//! Hot-path equivalence suite (the perf-overhaul PR's determinism
//! pins). Three bands — see `rust/tests/README.md` for triage:
//!
//! 1. **Queue** — the calendar [`EventQueue`] must be observationally
//!    equivalent to the frozen `BinaryHeap` reference
//!    ([`reference::HeapQueue`]): identical pop streams for arbitrary
//!    schedule/pop interleavings, including same-instant FIFO bursts
//!    and multi-"year" sparse gaps (testkit property, shrinking).
//! 2. **Machine differential** — `fast_paths` on ≡ off, bit for bit
//!    (float accumulators compared by bit pattern), over randomized
//!    shrinking action traces (mixed block classes, sleeps, type
//!    changes, oversubscription) and over `RunMany` vs unrolled `Run`
//!    streams.
//! 3. **End-to-end** — a small real web-server run and a 2-machine
//!    fleet must produce byte-identical rendered tables and bit-equal
//!    tails/energy with the fast paths on and off. This is the same
//!    property the golden snapshots rely on (they are recorded with the
//!    fast paths at their default, on).

use avxfreq::cpu::TurboTable;
use avxfreq::fleet::{run_fleet, FleetCfg, RouterSpec};
use avxfreq::isa::block::{Block, ClassMix, InsnClass};
use avxfreq::scenario::{ArrivalSpec, PolicySpec, ScenarioMatrix, TopologySpec, WorkloadSpec};
use avxfreq::sched::machine::{Action, Machine, MachineParams, NullDriver, TaskBody};
use avxfreq::sched::{PolicyKind, TaskType};
use avxfreq::sim::queue::reference::HeapQueue;
use avxfreq::sim::{EventQueue, Time, MS, SEC, US};
use avxfreq::testkit::{assert_prop, IntRange, VecOf};
use avxfreq::util::Rng;
use avxfreq::workload::client::LoadMode;
use avxfreq::workload::crypto::Isa;
use avxfreq::workload::webserver::{run_webserver, WebCfg, WebRun};
use std::cell::RefCell;
use std::rc::Rc;

// ---------------------------------------------------------------------
// Band 1: calendar queue ≡ heap reference.

/// Decode one raw trace value into a queue operation. `None` = pop;
/// `Some(delay)` = schedule at `now + delay`. The delay distribution
/// deliberately covers the same-instant burst (0), the dense
/// near-future the calendar is tuned for, and multi-"year" gaps that
/// force its sparse fallback.
fn decode_op(v: u64) -> Option<Time> {
    if v % 5 == 0 {
        return None;
    }
    Some(match (v / 5) % 4 {
        0 => 0,
        1 => v % 1_000,
        2 => v % 100_000,
        _ => v % 100_000_000, // ~100 wheel revolutions out
    })
}

#[test]
fn calendar_queue_matches_heap_reference() {
    let strat = VecOf { elem: IntRange { lo: 0, hi: u64::MAX / 2 }, max_len: 200 };
    assert_prop("calendar ≡ heap pop order", 0xC0FFEE, 60, &strat, |ops| {
        let mut cal: EventQueue<u64> = EventQueue::new();
        let mut heap: HeapQueue<u64> = HeapQueue::new();
        for (i, &v) in ops.iter().enumerate() {
            match decode_op(v) {
                None => {
                    let (a, b) = (cal.pop(), heap.pop());
                    if a != b {
                        return Err(format!("op {i}: pop {a:?} != reference {b:?}"));
                    }
                }
                Some(delay) => {
                    cal.schedule_in(delay, i as u64);
                    heap.schedule_in(delay, i as u64);
                }
            }
            if cal.len() != heap.len() {
                return Err(format!("op {i}: len {} != {}", cal.len(), heap.len()));
            }
            if cal.peek_time() != heap.peek_time() {
                return Err(format!(
                    "op {i}: peek {:?} != {:?}",
                    cal.peek_time(),
                    heap.peek_time()
                ));
            }
        }
        // Drain: the tails must agree too.
        loop {
            let (a, b) = (cal.pop(), heap.pop());
            if a != b {
                return Err(format!("drain: {a:?} != {b:?}"));
            }
            if a.is_none() {
                return Ok(());
            }
        }
    });
}

#[test]
fn calendar_queue_same_instant_burst_is_fifo() {
    // A large burst at one instant interleaved with pops: strict
    // insertion order must survive the calendar's bucket selection.
    let mut q = EventQueue::new();
    q.schedule_at(1000, 0u64);
    q.pop();
    for i in 1..=500u64 {
        q.schedule_at(1000, i);
    }
    for want in 1..=500u64 {
        let (t, got) = q.pop().unwrap();
        assert_eq!((t, got), (1000, want));
    }
}

// ---------------------------------------------------------------------
// Band 2: machine differential over shrinking action traces.

/// Body replaying a fixed action script, then exiting.
struct ScriptBody {
    actions: Vec<Action>,
    pos: usize,
    done: Rc<RefCell<u64>>,
}

impl TaskBody for ScriptBody {
    fn next(&mut self, _now: Time, _rng: &mut Rng) -> Action {
        match self.actions.get(self.pos) {
            Some(a) => {
                self.pos += 1;
                a.clone()
            }
            None => {
                *self.done.borrow_mut() += 1;
                Action::Exit
            }
        }
    }
}

/// Decode a raw trace value into one action of a mixed workload.
fn decode_action(v: u64) -> Action {
    if v % 13 == 0 {
        return Action::Sleep((v % 3 + 1) * 50 * US);
    }
    if v % 11 == 0 {
        return Action::SetType(if v % 2 == 0 { TaskType::Avx } else { TaskType::Scalar });
    }
    let insns = 1_000 + v % 30_000;
    let mix = match v % 4 {
        0 | 1 => ClassMix::scalar(insns),
        2 => ClassMix::of(InsnClass::Avx512Heavy, insns),
        _ => ClassMix::of(InsnClass::Avx2Heavy, insns).with(InsnClass::Scalar, insns / 4),
    };
    Action::Run {
        block: Block { mix, mem_ops: insns / 10, branches: insns / 50, license_exempt: false },
        func: v % 9,
        stack: 0,
    }
}

/// Bit-pattern fingerprint of a machine run (floats via `to_bits`).
fn machine_fingerprint(m: &Machine) -> Vec<u64> {
    let p = m.total_perf();
    vec![
        p.instructions,
        p.cycles,
        p.branches,
        p.mispredicts,
        p.busy_ns,
        p.idle_ns,
        p.stall_ns,
        p.license_cycles[0],
        p.license_cycles[1],
        p.license_cycles[2],
        p.throttle_cycles,
        p.license_requests,
        p.freq_switches,
        p.freq_integral.to_bits(),
        p.active_energy_j.to_bits(),
        p.idle_energy_j.to_bits(),
        m.sched.stats.migrations,
        m.sched.stats.type_changes,
        m.now(),
    ]
}

fn run_script(trace: &[u64], fast: bool) -> (Vec<u64>, u64) {
    // 3 tasks on 2 cores (oversubscribed: quantum expiry and migrations
    // inside coalesced windows), CoreSpec so SetType suspends/migrates.
    let mut p = MachineParams::new(2, PolicyKind::CoreSpec { avx_cores: 1 });
    p.turbo = TurboTable::flat(2.8, 2.4, 1.9, 2);
    p.fast_paths = fast;
    let mut m = Machine::new(p);
    let done = Rc::new(RefCell::new(0u64));
    for t in 0..3usize {
        // Offset per task so the three scripts interleave differently.
        let actions: Vec<Action> =
            trace.iter().skip(t).map(|&v| decode_action(v.rotate_left(t as u32))).collect();
        m.spawn(
            TaskType::Scalar,
            0,
            Box::new(ScriptBody { actions, pos: 0, done: done.clone() }),
        );
    }
    m.run_until(30 * SEC, &mut NullDriver);
    (machine_fingerprint(&m), *done.borrow())
}

#[test]
fn fast_paths_differential_over_shrinking_traces() {
    let strat = VecOf { elem: IntRange { lo: 0, hi: u64::MAX / 2 }, max_len: 48 };
    assert_prop("fast on ≡ fast off (machine)", 0xFA57, 25, &strat, |trace| {
        let (fast, done_fast) = run_script(trace, true);
        let (slow, done_slow) = run_script(trace, false);
        if done_fast != done_slow {
            return Err(format!("completion drift: {done_fast} vs {done_slow}"));
        }
        if fast != slow {
            return Err(format!("fingerprint drift:\n fast {fast:?}\n slow {slow:?}"));
        }
        Ok(())
    });
}

/// `RunMany { reps }` must equal `reps` unrolled `Run`s under both path
/// selections — four runs, one fingerprint.
#[test]
fn run_many_differential_over_shrinking_traces() {
    let strat = VecOf { elem: IntRange { lo: 1, hi: 60 }, max_len: 12 };
    assert_prop("RunMany ≡ unrolled Run", 0xBA7C4, 20, &strat, |reps_trace| {
        let block = Block {
            mix: ClassMix::scalar(8_000),
            mem_ops: 400,
            branches: 160,
            license_exempt: false,
        };
        let build = |batched: bool| -> Vec<Action> {
            let mut out = Vec::new();
            for &k in reps_trace {
                let k = k as u32;
                if batched {
                    out.push(Action::RunMany { block: block.clone(), reps: k, func: 1, stack: 0 });
                } else {
                    for _ in 0..k {
                        out.push(Action::Run { block: block.clone(), func: 1, stack: 0 });
                    }
                }
                // A sleep between batches so wakes land mid-stream.
                out.push(Action::Sleep(120 * US));
            }
            out
        };
        let run = |batched: bool, fast: bool| -> Vec<u64> {
            let mut p = MachineParams::new(1, PolicyKind::Unmodified);
            p.turbo = TurboTable::flat(2.8, 2.4, 1.9, 1);
            p.fast_paths = fast;
            let mut m = Machine::new(p);
            let done = Rc::new(RefCell::new(0u64));
            for _ in 0..2 {
                m.spawn(
                    TaskType::Untyped,
                    0,
                    Box::new(ScriptBody { actions: build(batched), pos: 0, done: done.clone() }),
                );
            }
            m.run_until(30 * SEC, &mut NullDriver);
            machine_fingerprint(&m)
        };
        let base = run(false, false);
        for (batched, fast) in [(false, true), (true, false), (true, true)] {
            let got = run(batched, fast);
            if got != base {
                return Err(format!(
                    "divergence at batched={batched} fast={fast}:\n got {got:?}\n want {base:?}"
                ));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Band 3: end-to-end byte/bit equality.

fn small_web_cfg(fast: bool) -> WebCfg {
    let mut c = WebCfg::paper_default(Isa::Avx512, PolicyKind::CoreSpec { avx_cores: 1 });
    c.cores = 4;
    c.workers = 8;
    c.page_bytes = 8 * 1024;
    c.warmup = 100 * MS;
    c.measure = 250 * MS;
    c.mode = LoadMode::OpenProcess {
        process: avxfreq::traffic::ArrivalProcess::two_tenant(25_000.0, 0.3),
    };
    c.fast_paths = fast;
    c
}

fn web_fingerprint(r: &WebRun) -> Vec<u64> {
    let mut out = vec![
        r.completed,
        r.dropped,
        r.stats.violations(),
        r.throughput_rps.to_bits(),
        r.avg_ghz.to_bits(),
        r.ipc.to_bits(),
        r.insns_per_req.to_bits(),
        r.active_energy_j.to_bits(),
        r.idle_energy_j.to_bits(),
        r.tail.p50_us.to_bits(),
        r.tail.p95_us.to_bits(),
        r.tail.p99_us.to_bits(),
        r.tail.p999_us.to_bits(),
        r.tail.max_us.to_bits(),
        r.tail.slo_violation_frac.to_bits(),
    ];
    for (_, t) in &r.tenant_tails {
        out.push(t.completed);
        out.push(t.p99_us.to_bits());
        out.push(t.slo_violation_frac.to_bits());
    }
    out
}

#[test]
fn webserver_two_tenant_run_is_bit_identical() {
    let fast = run_webserver(&small_web_cfg(true));
    let slow = run_webserver(&small_web_cfg(false));
    assert_eq!(web_fingerprint(&fast), web_fingerprint(&slow));
}

#[test]
fn fleet_run_is_bit_identical_with_fast_paths() {
    let fleet = |fast: bool| {
        let mut cfg = small_web_cfg(fast);
        // Fleet-total rate over 2 machines; trace replay + router paths.
        cfg.mode = LoadMode::OpenProcess {
            process: avxfreq::traffic::ArrivalProcess::two_tenant(50_000.0, 0.3),
        };
        let f = FleetCfg::new(2, RouterSpec::LeastOutstanding { service_est: 300_000 }, cfg);
        run_fleet(&f, 2)
    };
    let a = fleet(true);
    let b = fleet(false);
    assert_eq!(a.machines.len(), b.machines.len());
    for (ma, mb) in a.machines.iter().zip(&b.machines) {
        assert_eq!(web_fingerprint(ma), web_fingerprint(mb));
    }
    assert_eq!(web_fingerprint(&a.cluster_run()), web_fingerprint(&b.cluster_run()));
}

#[test]
fn matrix_tables_render_byte_identically_with_fast_paths() {
    // The golden-byte mechanism: the same (small, real) matrix rendered
    // with the fast paths on and off must be byte-for-byte equal — the
    // checked-in golden snapshots therefore cannot distinguish the two.
    let run = |fast: bool| {
        let mut m = ScenarioMatrix::new(0xBE7C);
        m.topologies = vec![TopologySpec::multi(1, 4)];
        m.policies = vec![PolicySpec::CoreSpec { avx_cores: 1 }];
        m.workloads = vec![WorkloadSpec {
            name: "small".to_string(),
            compress: true,
            page_kib: 8,
            rate_per_core: 4_000.0,
        }];
        m.isas = vec![Isa::Avx512];
        m.loads = vec![0.8, 1.2];
        m.arrivals = vec![ArrivalSpec::Poisson, ArrivalSpec::bursty_default()];
        m.warmup = 100 * MS;
        m.measure = 200 * MS;
        m.fast_paths = fast;
        let r = m.run(2);
        (r.render(), r.render_tail())
    };
    let (tbl_fast, tail_fast) = run(true);
    let (tbl_slow, tail_slow) = run(false);
    assert_eq!(tbl_fast, tbl_slow, "matrix table bytes differ across fast-path setting");
    assert_eq!(tail_fast, tail_slow, "tail table bytes differ across fast-path setting");
}
