//! Golden-file tests for the report renderers: `metrics::matrix_report`
//! and the new tail-latency table must render byte-identically to the
//! checked-in snapshots, and a real (small) matrix must render the same
//! bytes at 1 and 4 OS threads.
//!
//! The snapshots are driven by *synthetic* cell results with fixed
//! values, so they pin the formatting contract (column set, ordering,
//! fixed precision, alignment) independently of the simulator. To
//! regenerate after an intentional format change:
//! `UPDATE_GOLDEN=1 cargo test --test golden_report`.

use avxfreq::cpu::GovernorSpec;
use avxfreq::fleet::{BalancerCfg, HierFleetRun, RouterSpec};
use avxfreq::metrics::{hier_report, matrix_report, tail_report};
use avxfreq::repro::fleetscale::{self, ScaleRow};
use avxfreq::scenario::{
    ArrivalSpec, CellResult, ExecutorSpec, FaultSpec, PolicySpec, Scenario, ScenarioMatrix,
    TopologySpec, WorkloadSpec,
};
use avxfreq::sched::PolicyKind;
use avxfreq::sim::MS;
use avxfreq::traffic::{FrontendOutcomes, LatencyStats, TailSummary};
use avxfreq::workload::crypto::Isa;
use avxfreq::workload::webserver::{WebCfg, WebRun};

fn tail(completed: u64, p50: f64, p95: f64, p99: f64, p999: f64, max: f64, frac: f64) -> TailSummary {
    TailSummary {
        completed,
        mean_us: p50, // unused by the tables; any value works
        p50_us: p50,
        p95_us: p95,
        p99_us: p99,
        p999_us: p999,
        max_us: max,
        slo_us: 5_000.0,
        slo_violation_frac: frac,
    }
}

#[allow(clippy::too_many_arguments)]
fn cell(
    index: usize,
    isa: Isa,
    policy: &str,
    arrival: &str,
    load: f64,
    rps: f64,
    t: TailSummary,
    tenants: Vec<(String, TailSummary)>,
) -> CellResult {
    let scenario = Scenario {
        index,
        topology: "1x12".to_string(),
        sockets: 1,
        policy: policy.to_string(),
        workload: "compressed".to_string(),
        isa,
        load,
        arrival: arrival.to_string(),
        fleet: 1,
        router: RouterSpec::RoundRobin,
        governor: GovernorSpec::IntelLegacy,
        executor: ExecutorSpec::Kernel,
        balancer: BalancerCfg::default(),
        faults: FaultSpec::None,
        measure_point: None,
        seed: 7,
        cfg: WebCfg::paper_default(isa, PolicyKind::Unmodified),
    };
    let n_tenants = tenants.len();
    let run = WebRun {
        cfg_name: "synthetic".to_string(),
        throughput_rps: rps,
        avg_ghz: 2.75,
        ipc: 1.5,
        insns_per_req: 1_000_000.0,
        tail: t,
        tenant_tails: tenants,
        stats: LatencyStats::new(5 * MS),
        tenant_stats: (0..n_tenants).map(|_| LatencyStats::new(5 * MS)).collect(),
        dropped: if index == 1 { 25 } else { 0 },
        type_changes_per_sec: 9_000.0,
        migrations_per_sec: 1_200.0,
        cross_socket_migrations_per_sec: 0.0,
        runtime_steered: 0,
        runtime_migrations: 0,
        runtime_migrations_per_sec: 0.0,
        runtime_preemptions: 0,
        active_energy_j: 0.0,
        idle_energy_j: 0.0,
        throttle_ratio: 0.0625,
        license_share: [0.75, 0.125, 0.125],
        completed: t.completed,
        final_avx_cores: 2,
        adaptive_changes: 0,
        domain_ghz: Vec::new(),
    };
    CellResult { scenario, run, fleet: None, hier: None }
}

/// Two fixed cells: a single-tenant Poisson cell and a two-tenant bursty
/// cell, covering both table shapes (one row vs two rows per cell).
fn synthetic_cells() -> Vec<CellResult> {
    let t0 = tail(48_000, 250.0, 1_500.0, 2_000.0, 3_500.0, 8_000.0, 0.125);
    let ta = tail(45_000, 200.0, 1_000.0, 1_250.0, 2_500.0, 6_000.0, 0.0625);
    let tb = tail(15_000, 400.0, 2_000.0, 3_000.0, 5_500.0, 12_000.0, 0.25);
    let agg = tail(60_000, 250.0, 1_250.0, 2_000.0, 4_500.0, 12_000.0, 0.109375);
    vec![
        cell(
            0,
            Isa::Avx512,
            "unmodified",
            "poisson",
            1.0,
            48_000.0,
            t0,
            vec![("all".to_string(), t0)],
        ),
        cell(
            1,
            Isa::Avx512,
            "core-spec(2)",
            "bursty",
            1.25,
            60_000.0,
            agg,
            vec![("scalar".to_string(), ta), ("avx".to_string(), tb)],
        ),
    ]
}

fn check_golden(name: &str, actual: &str) {
    let path = format!("{}/rust/tests/golden/{name}.txt", env!("CARGO_MANIFEST_DIR"));
    if std::env::var("UPDATE_GOLDEN").is_ok() {
        std::fs::write(&path, actual).expect("write golden");
        eprintln!("updated {path}");
        return;
    }
    let expected = std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("{path}: {e}"));
    assert!(
        actual == expected,
        "{name} drifted from its snapshot ({path}).\n--- expected ---\n{expected}\n--- actual ---\n{actual}\n\
         Run with UPDATE_GOLDEN=1 if the change is intentional."
    );
}

#[test]
fn matrix_report_matches_snapshot() {
    check_golden("matrix_report", &matrix_report(&synthetic_cells()).render());
}

#[test]
fn tail_report_matches_snapshot() {
    check_golden("tail_report", &tail_report(&synthetic_cells()).render());
}

/// Synthetic hierarchical run pinning `metrics::hier_report`: two racks
/// whose recorders each hold a single value (a single-value recorder's
/// percentiles are exact, so the rack rows are fully predictable) plus
/// a hand-written cluster tail and front-end outcome counters.
fn synthetic_hier_run() -> HierFleetRun {
    let mut rack0 = LatencyStats::new(5 * MS);
    rack0.record(1_500 * 1_000); // 1500 µs, within SLO
    let mut rack1 = LatencyStats::new(5 * MS);
    rack1.record(2_500 * 1_000); // 2500 µs, within SLO
    HierFleetRun {
        router: "rr".to_string(),
        balancer: "closed(4ep)".to_string(),
        machines: 4,
        machines_per_rack: 2,
        digests: Vec::new(),
        racks: vec![rack0, rack1],
        stats: LatencyStats::new(5 * MS),
        tail: tail(60_000, 250.0, 1_250.0, 2_000.0, 4_500.0, 12_000.0, 0.109375),
        tenant_stats: Vec::new(),
        outcomes: FrontendOutcomes {
            timeouts_observed: 12,
            retries_issued: 9,
            retries_abandoned: 3,
            hedges_issued: 7,
            ejections: 1,
            readmissions: 1,
        },
        fault_outcomes: Default::default(),
        fault_windows: Vec::new(),
        completed: 60_000,
        dropped: 25,
        violations: 6_562,
        measure_secs: 2.0,
        collective: None,
    }
}

#[test]
fn hier_report_matches_snapshot() {
    let run = synthetic_hier_run();
    check_golden("hier_report", &hier_report(&[("fleet", &run)]).render());
}

#[test]
fn fleetscale_report_matches_snapshot() {
    // Values chosen exactly representable at the printed precision so
    // the rendering is independent of float-rounding ties.
    let rows = vec![
        ScaleRow {
            arm: "rr/unmod".to_string(),
            machines: 2,
            fleet_p99_us: 5_000.0,
            sigma_us: 120.5,
            spread_us: 340.0,
            slo_pct: 12.5,
            steps: 500,
            makespan_ms: 2_750.0,
            slowdown: 1.1,
        },
        ScaleRow {
            arm: "rr/unmod".to_string(),
            machines: 16,
            fleet_p99_us: 9_000.0,
            sigma_us: 480.3,
            spread_us: 1_250.0,
            slo_pct: 18.8,
            steps: 500,
            makespan_ms: 4_125.0,
            slowdown: 1.65,
        },
        ScaleRow {
            arm: "avx-part/core-spec".to_string(),
            machines: 16,
            fleet_p99_us: 5_500.0,
            sigma_us: 95.5,
            spread_us: 310.0,
            slo_pct: 6.2,
            steps: 500,
            makespan_ms: 2_887.5,
            slowdown: 1.15,
        },
    ];
    check_golden("fleetscale_report", &fleetscale::table(&rows).render());
}

/// The renderer side of the determinism acceptance criterion: a real
/// (small) traffic matrix renders byte-identical matrix AND tail tables
/// at 1 and 4 OS threads.
#[test]
fn real_matrix_renders_identically_at_1_and_4_threads() {
    let mut m = ScenarioMatrix::new(0x7A11);
    m.topologies = vec![TopologySpec::multi(1, 4)];
    m.policies = vec![PolicySpec::CoreSpec { avx_cores: 1 }];
    m.workloads = vec![WorkloadSpec {
        name: "small".to_string(),
        compress: true,
        page_kib: 8,
        rate_per_core: 4_000.0,
    }];
    m.isas = vec![Isa::Avx512];
    m.loads = vec![0.8, 1.2];
    m.arrivals = vec![ArrivalSpec::Poisson, ArrivalSpec::bursty_default()];
    m.warmup = 100 * MS;
    m.measure = 200 * MS;

    let serial = m.run(1);
    let parallel = m.run(4);
    assert_eq!(serial.render(), parallel.render(), "matrix table differs across threads");
    assert_eq!(
        serial.render_tail(),
        parallel.render_tail(),
        "tail table differs across threads"
    );
}
