//! Integration tests for the fleet layer: the histogram/latency-recorder
//! merge laws the cross-machine aggregation depends on, the
//! size-1-fleet ≡ single-machine differential property, cross-thread /
//! cross-ordering determinism of fleet runs and fleet matrix sweeps,
//! golden-file snapshots for the fleet tables, and the headline
//! behavioral claim: AVX-aware routing reduces cross-machine p99 spread
//! vs round-robin on the bursty multi-tenant mix.

use avxfreq::fleet::{route_stream, run_fleet, FleetCfg, FleetRun, RouterSpec};
use avxfreq::metrics::fleet_report;
use avxfreq::repro::fleetvar::{table as fleetvar_table, RouterVar};
use avxfreq::scenario::{ArrivalSpec, PolicySpec, ScenarioMatrix, TopologySpec, WorkloadSpec};
use avxfreq::sched::PolicyKind;
use avxfreq::sim::MS;
use avxfreq::testkit::{assert_prop, IntRange, VecOf};
use avxfreq::traffic::{ArrivalProcess, LatencyStats, TailSummary};
use avxfreq::util::LogHistogram;
use avxfreq::workload::client::LoadMode;
use avxfreq::workload::crypto::Isa;
use avxfreq::workload::webserver::{run_webserver, WebCfg, WebRun};

// ---------------------------------------------------------------------
// Merge laws (the fleet aggregation path depends on these)
// ---------------------------------------------------------------------

/// Structural equality of two histograms through their whole query
/// surface: counts, extrema, mean, a grid of percentiles, and
/// threshold queries.
fn hist_eq(a: &LogHistogram, b: &LogHistogram) -> Result<(), String> {
    if a.count() != b.count() {
        return Err(format!("count {} != {}", a.count(), b.count()));
    }
    if a.min() != b.min() || a.max() != b.max() {
        return Err(format!("extrema ({},{}) != ({},{})", a.min(), a.max(), b.min(), b.max()));
    }
    if a.mean() != b.mean() {
        return Err(format!("mean {} != {}", a.mean(), b.mean()));
    }
    for p in [1.0, 25.0, 50.0, 90.0, 99.0, 99.9, 100.0] {
        if a.percentile(p) != b.percentile(p) {
            return Err(format!("p{p}: {} != {}", a.percentile(p), b.percentile(p)));
        }
    }
    for v in [0, 100, 10_000, 1_000_000, u64::MAX / 2] {
        if a.fraction_above(v) != b.fraction_above(v) {
            return Err(format!("fraction_above({v}) differs"));
        }
    }
    Ok(())
}

fn hist_of(samples: &[u64]) -> LogHistogram {
    let mut h = LogHistogram::new();
    for &s in samples {
        h.record(s);
    }
    h
}

/// LogHistogram::merge is commutative, associative, and equal to
/// recording the concatenated samples — on arbitrary sample vectors.
#[test]
fn prop_histogram_merge_laws() {
    let strat = VecOf { elem: IntRange { lo: 0, hi: 50_000_000 }, max_len: 200 };
    assert_prop("histogram merge laws", 0xF1EE7, 60, &strat, |samples| {
        // Deterministic 3-way split of the sample stream.
        let parts: Vec<Vec<u64>> = (0..3usize)
            .map(|k| samples.iter().copied().skip(k).step_by(3).collect())
            .collect();
        let (h0, h1, h2) = (hist_of(&parts[0]), hist_of(&parts[1]), hist_of(&parts[2]));
        // Commutative.
        let mut ab = h0.clone();
        ab.merge(&h1);
        let mut ba = h1.clone();
        ba.merge(&h0);
        hist_eq(&ab, &ba).map_err(|e| format!("commutativity: {e}"))?;
        // Associative.
        let mut left = ab.clone();
        left.merge(&h2);
        let mut bc = h1.clone();
        bc.merge(&h2);
        let mut right = h0.clone();
        right.merge(&bc);
        hist_eq(&left, &right).map_err(|e| format!("associativity: {e}"))?;
        // Merge-equals-concat: merging the parts equals recording the
        // union of samples.
        let union: Vec<u64> = parts.iter().flatten().copied().collect();
        hist_eq(&left, &hist_of(&union)).map_err(|e| format!("merge-vs-union: {e}"))?;
        Ok(())
    });
}

fn stats_of(samples: &[u64], slo: u64) -> LatencyStats {
    let mut s = LatencyStats::new(slo);
    for &v in samples {
        s.record(v);
    }
    s
}

fn summary_eq(a: &TailSummary, b: &TailSummary) -> Result<(), String> {
    let pairs = [
        (a.mean_us, b.mean_us),
        (a.p50_us, b.p50_us),
        (a.p95_us, b.p95_us),
        (a.p99_us, b.p99_us),
        (a.p999_us, b.p999_us),
        (a.max_us, b.max_us),
        (a.slo_us, b.slo_us),
        (a.slo_violation_frac, b.slo_violation_frac),
    ];
    if a.completed != b.completed {
        return Err(format!("completed {} != {}", a.completed, b.completed));
    }
    for (x, y) in pairs {
        if x != y {
            return Err(format!("summary field {x} != {y}"));
        }
    }
    Ok(())
}

/// LatencyStats::merge preserves the same laws *including the exact
/// violation counter* — merging two recorders equals recording the
/// union of their samples.
#[test]
fn prop_latency_stats_merge_laws() {
    let slo = 5 * MS;
    let strat = VecOf { elem: IntRange { lo: 1, hi: 40_000_000 }, max_len: 150 };
    assert_prop("latency-stats merge laws", 0x51075, 60, &strat, |samples| {
        let (a, b): (Vec<u64>, Vec<u64>) =
            samples.iter().partition(|&&v| v % 2 == 0);
        let (sa, sb) = (stats_of(&a, slo), stats_of(&b, slo));
        let mut ab = sa.clone();
        ab.merge(&sb);
        let mut ba = sb.clone();
        ba.merge(&sa);
        if ab.violations() != ba.violations() || ab.completed() != ba.completed() {
            return Err("merge not commutative on exact counters".to_string());
        }
        let union = stats_of(samples, slo);
        if ab.violations() != union.violations() {
            return Err(format!(
                "violations {} != union {}",
                ab.violations(),
                union.violations()
            ));
        }
        if ab.violation_frac() != union.violation_frac() {
            return Err("violation fraction differs from union".to_string());
        }
        summary_eq(&ab.summary(), &union.summary())
    });
}

// ---------------------------------------------------------------------
// Differential: a fleet of size 1 IS the single-machine run
// ---------------------------------------------------------------------

fn small_cfg(seed: u64) -> WebCfg {
    let mut c = WebCfg::paper_default(Isa::Avx512, PolicyKind::CoreSpec { avx_cores: 1 });
    c.cores = 4;
    c.workers = 8;
    c.page_bytes = 8 * 1024;
    c.warmup = 120 * MS;
    c.measure = 300 * MS;
    c.seed = seed;
    c.mode = LoadMode::OpenProcess {
        process: ArrivalProcess::two_tenant(30_000.0, 0.3),
    };
    c
}

fn assert_runs_identical(a: &WebRun, b: &WebRun, what: &str) {
    assert_eq!(a.completed, b.completed, "{what}: completed");
    assert_eq!(a.dropped, b.dropped, "{what}: dropped");
    assert_eq!(a.stats.violations(), b.stats.violations(), "{what}: violations");
    assert_eq!(a.throughput_rps, b.throughput_rps, "{what}: throughput");
    assert_eq!(a.avg_ghz, b.avg_ghz, "{what}: GHz");
    assert_eq!(a.ipc, b.ipc, "{what}: IPC");
    summary_eq(&a.tail, &b.tail).unwrap_or_else(|e| panic!("{what}: tail {e}"));
    assert_eq!(a.tenant_tails.len(), b.tenant_tails.len(), "{what}: tenants");
    for ((na, ta), (nb, tb)) in a.tenant_tails.iter().zip(&b.tenant_tails) {
        assert_eq!(na, nb, "{what}: tenant name");
        summary_eq(ta, tb).unwrap_or_else(|e| panic!("{what}: tenant {na} {e}"));
    }
}

/// A fleet of size 1 — under *any* router — is byte-identical to the
/// standalone web-server run for the same seed and config: the same
/// TailSummary, the same exact SLO-violation count, the same counters.
#[test]
fn fleet_of_one_is_identical_to_single_machine() {
    let cfg = small_cfg(0xD1FF);
    let single = run_webserver(&cfg);
    assert!(single.completed > 500, "baseline served {}", single.completed);
    for router in [
        RouterSpec::RoundRobin,
        RouterSpec::least_outstanding(),
        RouterSpec::AvxPartition { avx_machines: 1 },
    ] {
        let fleet = run_fleet(&FleetCfg::new(1, router, cfg.clone()), 2);
        assert_eq!(fleet.machines.len(), 1);
        assert_runs_identical(&single, &fleet.machines[0], &router.label());
        // The cluster aggregate of one machine is that machine.
        assert_eq!(fleet.completed, single.completed, "{}", router.label());
        assert_eq!(fleet.violations, single.stats.violations());
        summary_eq(&fleet.tail, &single.tail)
            .unwrap_or_else(|e| panic!("{}: cluster tail {e}", router.label()));
    }
}

// ---------------------------------------------------------------------
// Determinism across threads and machine-simulation orderings
// ---------------------------------------------------------------------

/// Fleet runs are byte-identical at any worker-thread count (and hence
/// across machine-simulation orderings — the atomic-cursor claim order
/// differs run to run at 4 threads).
#[test]
fn fleet_deterministic_across_threads_and_orderings() {
    let mut cfg = small_cfg(0x0D37);
    cfg.mode = LoadMode::OpenProcess {
        process: ArrivalProcess::bursty_two_tenant(45_000.0, 0.3, 1.5, 0.3, 80 * MS),
    };
    let fleet = FleetCfg::new(3, RouterSpec::AvxPartition { avx_machines: 1 }, cfg);
    let serial = run_fleet(&fleet, 1);
    let parallel = run_fleet(&fleet, 4);
    let again = run_fleet(&fleet, 4);
    let render = |f: &FleetRun| fleet_report(&[("fleet", f)]).render();
    assert_eq!(render(&serial), render(&parallel), "1 vs 4 threads differ");
    assert_eq!(render(&parallel), render(&again), "two 4-thread runs differ");
    let completed = |f: &FleetRun| -> Vec<u64> { f.machines.iter().map(|m| m.completed).collect() };
    assert_eq!(completed(&serial), completed(&parallel));
    assert_eq!(serial.violations, parallel.violations);
}

/// The fleet axes ride through the scenario matrix deterministically:
/// a sweep over fleet sizes × routers renders byte-identical matrix,
/// tail, and fleet tables at 1 and 4 OS threads.
#[test]
fn fleet_matrix_deterministic_across_threads() {
    let mut m = ScenarioMatrix::new(0xF13E7);
    m.topologies = vec![TopologySpec::multi(1, 4)];
    m.policies = vec![PolicySpec::Unmodified];
    m.workloads = vec![WorkloadSpec {
        name: "small".to_string(),
        compress: true,
        page_kib: 8,
        rate_per_core: 4_000.0,
    }];
    m.isas = vec![Isa::Avx512];
    m.arrivals = vec![ArrivalSpec::BurstyMix {
        avx_share: 0.3,
        burst_factor: 1.5,
        duty: 0.3,
        period: 80 * MS,
    }];
    m.fleet_sizes = vec![1, 2];
    m.routers = vec![RouterSpec::RoundRobin, RouterSpec::AvxPartition { avx_machines: 1 }];
    m.warmup = 100 * MS;
    m.measure = 200 * MS;
    assert_eq!(m.len(), 4);

    let serial = m.run(1);
    let parallel = m.run(4);
    assert_eq!(serial.render(), parallel.render(), "matrix table differs");
    assert_eq!(serial.render_tail(), parallel.render_tail(), "tail table differs");
    assert_eq!(serial.render_fleet(), parallel.render_fleet(), "fleet table differs");
    // Cells with non-default fleet axes carry the full FleetRun; the
    // size-1 round-robin cell bypasses the fleet layer.
    assert!(serial.cells[0].fleet.is_none(), "size-1 round-robin is the classic cell");
    assert!(serial.cells[1].fleet.is_some(), "size-1 avx-partition runs as a fleet");
    assert_eq!(serial.cells[3].fleet.as_ref().unwrap().machines.len(), 2);
    for cell in &serial.cells {
        assert!(
            cell.run.completed > 50,
            "{} only completed {}",
            cell.scenario.label(),
            cell.run.completed
        );
    }
}

// ---------------------------------------------------------------------
// Golden snapshots for the fleet tables (synthetic, formatting only)
// ---------------------------------------------------------------------

fn check_golden(name: &str, actual: &str) {
    let path = format!("{}/rust/tests/golden/{name}.txt", env!("CARGO_MANIFEST_DIR"));
    if std::env::var("UPDATE_GOLDEN").is_ok() {
        std::fs::write(&path, actual).expect("write golden");
        eprintln!("updated {path}");
        return;
    }
    let expected = std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("{path}: {e}"));
    assert!(
        actual == expected,
        "{name} drifted from its snapshot ({path}).\n--- expected ---\n{expected}\n--- actual ---\n{actual}\n\
         Run with UPDATE_GOLDEN=1 if the change is intentional."
    );
}

fn synthetic_webrun(done: u64, p50: f64, p99: f64, p999: f64, frac: f64, drops: u64) -> WebRun {
    let tail = TailSummary {
        completed: done,
        mean_us: p50,
        p50_us: p50,
        p95_us: p99,
        p99_us: p99,
        p999_us: p999,
        max_us: p999,
        slo_us: 10_000.0,
        slo_violation_frac: frac,
    };
    WebRun {
        cfg_name: "synthetic".to_string(),
        throughput_rps: done as f64,
        avg_ghz: 2.75,
        ipc: 1.5,
        insns_per_req: 1_000_000.0,
        tail,
        tenant_tails: vec![("all".to_string(), tail)],
        stats: LatencyStats::new(10 * MS),
        tenant_stats: vec![LatencyStats::new(10 * MS)],
        dropped: drops,
        type_changes_per_sec: 0.0,
        migrations_per_sec: 0.0,
        cross_socket_migrations_per_sec: 0.0,
        runtime_steered: 0,
        runtime_migrations: 0,
        runtime_migrations_per_sec: 0.0,
        runtime_preemptions: 0,
        active_energy_j: 0.0,
        idle_energy_j: 0.0,
        throttle_ratio: 0.0,
        license_share: [1.0, 0.0, 0.0],
        completed: done,
        final_avx_cores: 0,
        adaptive_changes: 0,
        domain_ghz: Vec::new(),
    }
}

/// Fixed synthetic fleet covering both row shapes (machine rows and the
/// cluster row with the dispersion columns).
fn synthetic_fleet() -> FleetRun {
    let m0 = synthetic_webrun(3600, 250.0, 1000.0, 2000.0, 0.025, 0);
    let m1 = synthetic_webrun(900, 400.0, 3000.0, 5000.0, 0.1, 3);
    let cluster_tail = TailSummary {
        completed: 4500,
        mean_us: 275.0,
        p50_us: 275.0,
        p95_us: 1500.0,
        p99_us: 1500.0,
        p999_us: 4000.0,
        max_us: 5000.0,
        slo_us: 10_000.0,
        slo_violation_frac: 0.04,
    };
    FleetRun {
        router: "avx-part(1)".to_string(),
        machines: vec![m0, m1],
        arrivals_routed: vec![4000, 1000],
        stats: LatencyStats::new(10 * MS),
        tail: cluster_tail,
        tenant_stats: Vec::new(),
        completed: 4500,
        dropped: 3,
        violations: 180,
        measure_secs: 1.0,
    }
}

#[test]
fn fleet_report_matches_snapshot() {
    let f = synthetic_fleet();
    check_golden("fleet_report", &fleet_report(&[("f0", &f)]).render());
}

#[test]
fn fleetvar_report_matches_snapshot() {
    let rows = vec![
        RouterVar {
            router: "round-robin".to_string(),
            machines: 6,
            fleet_p99_us: 9000.0,
            mean_p99_us: 8500.0,
            sigma_us: 2400.0,
            spread_us: 6800.0,
            slo_pct: 18.0,
        },
        RouterVar {
            router: "avx-part(1)".to_string(),
            machines: 6,
            fleet_p99_us: 2600.0,
            mean_p99_us: 2500.0,
            sigma_us: 300.0,
            spread_us: 800.0,
            slo_pct: 2.0,
        },
    ];
    check_golden("fleetvar_report", &fleetvar_table(&rows).render());
}

// ---------------------------------------------------------------------
// The headline behavioral claim
// ---------------------------------------------------------------------

/// The fleetvar scenario scaled down to test size: uncompressed
/// (crypto-dominated) pages on small machines, a 30% AVX-512 tenant with
/// in-phase bursts, and the AVX subset sized to the AVX share of *work*
/// (AVX-512 requests are instruction-cheap), so every partitioned
/// machine runs at lower utilization than any round-robin machine.
fn bursty_mix_fleet(router: RouterSpec) -> FleetCfg {
    let mut cfg = WebCfg::paper_default(Isa::Avx512, PolicyKind::Unmodified);
    cfg.cores = 3;
    cfg.workers = 6;
    cfg.compress = false;
    cfg.page_bytes = 384 * 1024;
    cfg.annotate = false;
    cfg.seed = 0xF1EE;
    cfg.slo = 25 * MS;
    cfg.warmup = 150 * MS;
    cfg.measure = 500 * MS;
    // Mean fleet rate at the round-robin knee: every mixed machine
    // rides the drain-or-ratchet edge (maximum cross-machine variance)
    // while both partitioned groups sit ~8–17% below it and drain every
    // burst.
    cfg.mode = LoadMode::OpenProcess {
        process: ArrivalProcess::bursty_two_tenant(90_000.0, 0.3, 1.5, 0.3, 90 * MS),
    };
    FleetCfg::new(6, router, cfg)
}

/// Satellite acceptance: `AvxPartition` reduces cross-machine p99
/// spread (and σ) vs round-robin on the bursty multi-tenant mix, and —
/// structurally — the scalar majority of the fleet never executes a
/// single licensed wide instruction, exactly like the paper's scalar
/// cores.
#[test]
fn avx_partition_reduces_cross_machine_p99_spread_on_bursty_mix() {
    let rr = run_fleet(&bursty_mix_fleet(RouterSpec::RoundRobin), 4);
    let part = run_fleet(&bursty_mix_fleet(RouterSpec::AvxPartition { avx_machines: 1 }), 4);
    for (name, f) in [("round-robin", &rr), ("avx-partition", &part)] {
        for (i, m) in f.machines.iter().enumerate() {
            assert!(m.completed > 500, "{name} m{i} served only {}", m.completed);
        }
    }

    // Structural: scalar machines under the partition never see AVX
    // license levels; the AVX machine carries all of them.
    for (i, m) in part.machines.iter().enumerate().take(5) {
        assert_eq!(
            m.license_share[1], 0.0,
            "scalar machine {i} spent time at L1"
        );
        assert_eq!(
            m.license_share[2], 0.0,
            "scalar machine {i} spent time at L2"
        );
    }
    // Under round-robin every machine pays the license tax.
    for (i, m) in rr.machines.iter().enumerate() {
        assert!(
            m.license_share[1] + m.license_share[2] > 0.0,
            "round-robin machine {i} unexpectedly license-clean"
        );
    }

    // Headline: the straggler gap and the cross-machine dispersion
    // shrink under AVX-aware routing.
    let (rr_s, part_s) = (rr.p99_summary(), part.p99_summary());
    assert!(
        part.p99_spread_us() < rr.p99_spread_us(),
        "avx-partition must reduce cross-machine p99 spread: {:.0} vs {:.0} µs \
         (rr p99s {:?}, part p99s {:?})",
        part.p99_spread_us(),
        rr.p99_spread_us(),
        rr.p99s_us(),
        part.p99s_us()
    );
    assert!(
        part_s.stddev() < rr_s.stddev(),
        "avx-partition must reduce cross-machine p99 σ: {:.1} vs {:.1} µs",
        part_s.stddev(),
        rr_s.stddev()
    );
    // And the fleet-wide tail improves outright (merged histograms).
    assert!(
        part.tail.p99_us < rr.tail.p99_us,
        "fleet p99 must improve: {:.0} vs {:.0} µs",
        part.tail.p99_us,
        rr.tail.p99_us
    );
    assert!(part.tail.slo_violation_frac <= rr.tail.slo_violation_frac);
}

/// Router/tenant plumbing on the real stream: the partition router
/// sends every AVX-tenant arrival to the last machine and splits the
/// scalar majority round-robin; total arrivals are conserved.
#[test]
fn route_stream_conserves_and_partitions_arrivals() {
    let fleet = bursty_mix_fleet(RouterSpec::AvxPartition { avx_machines: 1 });
    let traces = route_stream(&fleet);
    assert_eq!(traces.len(), 6);
    assert!(traces[5].iter().all(|&(_, tenant)| tenant == 1), "last machine is the AVX subset");
    for (i, t) in traces.iter().enumerate().take(5) {
        assert!(t.iter().all(|&(_, tenant)| tenant == 0), "machine {i} got AVX work");
        assert!(!t.is_empty(), "scalar machine {i} got nothing");
    }
    let routed: usize = traces.iter().map(|t| t.len()).sum();
    let rr: usize = route_stream(&bursty_mix_fleet(RouterSpec::RoundRobin))
        .iter()
        .map(|t| t.len())
        .sum();
    assert_eq!(routed, rr, "routing must conserve the arrival stream");
}

/// The fleetvar repro declares the acceptance scenario (6 machines,
/// bursty multi-tenant mix, unmodified schedulers, 1-machine AVX
/// subset) without running it.
#[test]
fn fleetvar_scenario_shape() {
    let cfg = avxfreq::repro::fleetvar::fleet_cfg(
        RouterSpec::AvxPartition { avx_machines: 1 },
        true,
        7,
    );
    assert_eq!(cfg.machines, 6);
    assert_eq!(cfg.router, RouterSpec::AvxPartition { avx_machines: 1 });
    assert!(!cfg.cfg.compress, "fleetvar runs the crypto-dominated page");
    assert!(matches!(cfg.cfg.policy, PolicyKind::Unmodified));
    let process = cfg.cfg.mode.process().expect("open loop");
    assert_eq!(process.label(), "bursty-mix");
    assert_eq!(process.n_tenants(), 2);
    assert!(process.tenant_carries_avx(1) && !process.tenant_carries_avx(0));
    // Mean-preserving bursts: the declared fleet rate survives.
    assert!((process.mean_rate() - 500_000.0).abs() < 1.0);
}
