"""AOT lowering: JAX model → HLO text artifacts for the rust runtime.

Emits one artifact per lane width (the paper's SIMD-width axis):

    artifacts/chacha_w4.hlo.txt    # 4 lanes  ≈ SSE4 (128-bit)
    artifacts/chacha_w8.hlo.txt    # 8 lanes  ≈ AVX2 (256-bit)
    artifacts/chacha_w16.hlo.txt   # 16 lanes ≈ AVX-512 (512-bit)
    artifacts/manifest.txt         # shapes + word counts for the loader

HLO **text** is the interchange format, not ``.serialize()``: jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension
0.5.1 (the version the published ``xla`` crate binds) rejects; the text
parser reassigns ids (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import pathlib

import jax

jax.config.update("jax_enable_x64", True)  # Poly1305 limb products need u64

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402

WIDTHS = (4, 8, 16)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_width(lanes: int) -> str:
    key = jax.ShapeDtypeStruct((8,), jnp.uint32)
    nonce = jax.ShapeDtypeStruct((3,), jnp.uint32)
    msg = jax.ShapeDtypeStruct((model.RECORD_WORDS,), jnp.uint32)
    lowered = jax.jit(model.seal_record_fn(lanes)).lower(key, nonce, msg)
    return to_hlo_text(lowered)


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument("--widths", default="4,8,16")
    args = parser.parse_args()

    out_dir = pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    widths = [int(w) for w in args.widths.split(",")]

    manifest = [f"record_words={model.RECORD_WORDS}"]
    for w in widths:
        text = lower_width(w)
        path = out_dir / f"chacha_w{w}.hlo.txt"
        path.write_text(text)
        manifest.append(f"chacha_w{w}.hlo.txt lanes={w}")
        print(f"wrote {path} ({len(text)} chars)")
    (out_dir / "manifest.txt").write_text("\n".join(manifest) + "\n")
    print(f"wrote {out_dir / 'manifest.txt'}")


if __name__ == "__main__":
    main()
