"""Pure-numpy reference for ChaCha20-Poly1305 (RFC 7539).

This is the correctness oracle: the Pallas kernels and the JAX model are
checked against these functions (and these functions against the RFC test
vectors) in ``python/tests/``.

All APIs operate on little-endian u32 *words*; byte-level helpers convert
at the edges (the rust runtime does the same conversion).
"""

from __future__ import annotations

import numpy as np

CONSTANTS = np.array([0x61707865, 0x3320646E, 0x79622D32, 0x6B206574], dtype=np.uint32)


def bytes_to_words(b: bytes) -> np.ndarray:
    """Little-endian bytes → u32 words (length must be a multiple of 4)."""
    assert len(b) % 4 == 0, "byte length must be a multiple of 4"
    return np.frombuffer(b, dtype="<u4").astype(np.uint32)


def words_to_bytes(w: np.ndarray) -> bytes:
    return np.asarray(w).astype("<u4").tobytes()


def _rotl(x: np.ndarray, n: int) -> np.ndarray:
    x = x.astype(np.uint32)
    return ((x << np.uint32(n)) | (x >> np.uint32(32 - n))).astype(np.uint32)


def _quarter(state: np.ndarray, a: int, b: int, c: int, d: int) -> None:
    # In-place quarter round on a (16, ...) state array.
    state[a] = (state[a] + state[b]).astype(np.uint32)
    state[d] = _rotl(state[d] ^ state[a], 16)
    state[c] = (state[c] + state[d]).astype(np.uint32)
    state[b] = _rotl(state[b] ^ state[c], 12)
    state[a] = (state[a] + state[b]).astype(np.uint32)
    state[d] = _rotl(state[d] ^ state[a], 8)
    state[c] = (state[c] + state[d]).astype(np.uint32)
    state[b] = _rotl(state[b] ^ state[c], 7)


def chacha20_block(key: np.ndarray, counter: int, nonce: np.ndarray) -> np.ndarray:
    """One 64-byte keystream block as 16 u32 words (RFC 7539 §2.3)."""
    key = np.asarray(key, dtype=np.uint32)
    nonce = np.asarray(nonce, dtype=np.uint32)
    assert key.shape == (8,) and nonce.shape == (3,)
    init = np.concatenate(
        [CONSTANTS, key, np.array([counter], dtype=np.uint32), nonce]
    ).astype(np.uint32)
    state = init.copy()
    with np.errstate(over="ignore"):  # u32 wrap-around is the algorithm
        for _ in range(10):
            _quarter(state, 0, 4, 8, 12)
            _quarter(state, 1, 5, 9, 13)
            _quarter(state, 2, 6, 10, 14)
            _quarter(state, 3, 7, 11, 15)
            _quarter(state, 0, 5, 10, 15)
            _quarter(state, 1, 6, 11, 12)
            _quarter(state, 2, 7, 8, 13)
            _quarter(state, 3, 4, 9, 14)
        return (state + init).astype(np.uint32)


def chacha20_xor(key: np.ndarray, nonce: np.ndarray, counter0: int, msg_words: np.ndarray) -> np.ndarray:
    """XOR a message (u32 words, multiple of 16) with the keystream."""
    msg_words = np.asarray(msg_words, dtype=np.uint32)
    assert msg_words.size % 16 == 0, "message must be whole 64-byte blocks"
    n_blocks = msg_words.size // 16
    ks = np.concatenate(
        [chacha20_block(key, counter0 + i, nonce) for i in range(n_blocks)]
    )
    return (msg_words ^ ks).astype(np.uint32)


# ---- Poly1305 (RFC 7539 §2.5) -------------------------------------------

P1305 = (1 << 130) - 5


def poly1305_mac(msg: bytes, key32: bytes) -> bytes:
    """Poly1305 tag of ``msg`` under a 32-byte one-time key (bignum ref)."""
    r = int.from_bytes(key32[:16], "little") & 0x0FFFFFFC0FFFFFFC0FFFFFFC0FFFFFFF
    s = int.from_bytes(key32[16:32], "little")
    acc = 0
    for i in range(0, len(msg), 16):
        chunk = msg[i : i + 16]
        n = int.from_bytes(chunk, "little") + (1 << (8 * len(chunk)))
        acc = ((acc + n) * r) % P1305
    acc = (acc + s) % (1 << 128)
    return acc.to_bytes(16, "little")


def poly1305_key_gen(key: np.ndarray, nonce: np.ndarray) -> bytes:
    """One-time MAC key: first 32 bytes of keystream block 0 (§2.6)."""
    block = chacha20_block(key, 0, nonce)
    return words_to_bytes(block[:8])


def _pad16(b: bytes) -> bytes:
    return b + bytes(-len(b) % 16)


def seal(key: np.ndarray, nonce: np.ndarray, plaintext: bytes, aad: bytes = b"") -> tuple[bytes, bytes]:
    """ChaCha20-Poly1305 AEAD seal (§2.8). Returns (ciphertext, tag)."""
    padded = plaintext + bytes(-len(plaintext) % 64)
    ct_words = chacha20_xor(key, nonce, 1, bytes_to_words(padded))
    ct = words_to_bytes(ct_words)[: len(plaintext)]
    otk = poly1305_key_gen(key, nonce)
    mac_data = (
        _pad16(aad)
        + _pad16(ct)
        + len(aad).to_bytes(8, "little")
        + len(ct).to_bytes(8, "little")
    )
    return ct, poly1305_mac(mac_data, otk)


def open_(key: np.ndarray, nonce: np.ndarray, ct: bytes, tag: bytes, aad: bytes = b"") -> "bytes | None":
    """AEAD open; returns plaintext or None on tag mismatch."""
    otk = poly1305_key_gen(key, nonce)
    mac_data = (
        _pad16(aad)
        + _pad16(ct)
        + len(aad).to_bytes(8, "little")
        + len(ct).to_bytes(8, "little")
    )
    if poly1305_mac(mac_data, otk) != tag:
        return None
    padded = ct + bytes(-len(ct) % 64)
    pt_words = chacha20_xor(key, nonce, 1, bytes_to_words(padded))
    return words_to_bytes(pt_words)[: len(ct)]
