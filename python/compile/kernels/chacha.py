"""Layer-1 Pallas kernel: lane-parallel ChaCha20.

The SIMD-width axis of the paper (SSE4 / AVX2 / AVX-512) maps to the
kernel's **lane batch** ``W`` — how many 64-byte ChaCha blocks one grid
step computes side by side (4 ≈ 128-bit, 8 ≈ 256-bit, 16 ≈ 512-bit),
exactly how OpenSSL's vectorized ChaCha20 assigns blocks to SIMD lanes.

BlockSpec expresses the HBM↔VMEM schedule: each grid step streams a
``W·16``-word message tile into VMEM, generates the W keystream blocks
entirely in registers/VMEM, XORs, and streams the tile out. VMEM
footprint per step is 2 tiles + 16·W state words (see DESIGN.md §Perf).

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; numerics are identical (checked against ref.py and RFC
vectors in python/tests).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# "expa" "nd 3" "2-by" "te k"
CONSTANTS = (0x61707865, 0x3320646E, 0x79622D32, 0x6B206574)


def _rotl(x, n):
    return (x << jnp.uint32(n)) | (x >> jnp.uint32(32 - n))


def _quarter(s, a, b, c, d):
    s[a] = s[a] + s[b]
    s[d] = _rotl(s[d] ^ s[a], 16)
    s[c] = s[c] + s[d]
    s[b] = _rotl(s[b] ^ s[c], 12)
    s[a] = s[a] + s[b]
    s[d] = _rotl(s[d] ^ s[a], 8)
    s[c] = s[c] + s[d]
    s[b] = _rotl(s[b] ^ s[c], 7)


def _keystream_lanes(key, nonce, counters):
    """W keystream blocks for a (W,)-vector of counters → (W*16,) words.

    The 16 state words live as separate (W,)-vectors so every ChaCha
    operation is a full-width vector op over the lane axis — the MXU is
    irrelevant (integer code); this targets the VPU lanes.
    """
    w = counters.shape[0]
    s = [jnp.broadcast_to(jnp.uint32(c), (w,)) for c in CONSTANTS]
    s += [jnp.broadcast_to(key[i], (w,)) for i in range(8)]
    s.append(counters.astype(jnp.uint32))
    s += [jnp.broadcast_to(nonce[i], (w,)) for i in range(3)]
    init = list(s)
    for _ in range(10):
        _quarter(s, 0, 4, 8, 12)
        _quarter(s, 1, 5, 9, 13)
        _quarter(s, 2, 6, 10, 14)
        _quarter(s, 3, 7, 11, 15)
        _quarter(s, 0, 5, 10, 15)
        _quarter(s, 1, 6, 11, 12)
        _quarter(s, 2, 7, 8, 13)
        _quarter(s, 3, 4, 9, 14)
    out = [a + b for a, b in zip(s, init)]
    # (16, W) → word-major serialization: block l's word j at l*16+j.
    return jnp.stack(out, axis=0).T.reshape(w * 16)


def _kernel(key_ref, nonce_ref, ctr_ref, msg_ref, out_ref, *, lanes: int):
    i = pl.program_id(0)
    lane = jax.lax.iota(jnp.uint32, lanes)
    counters = ctr_ref[0] + jnp.uint32(i * lanes) + lane
    ks = _keystream_lanes(key_ref[...], nonce_ref[...], counters)
    out_ref[...] = msg_ref[...] ^ ks


@functools.partial(jax.jit, static_argnames=("lanes",))
def chacha20_xor(key, nonce, counter0, msg_words, *, lanes: int = 16):
    """XOR ``msg_words`` (u32, multiple of 16·lanes) with the keystream.

    ``counter0`` is the block counter of the first message block, shape
    (1,) u32 (RFC 7539 encryption uses counter0 = 1).
    """
    n = msg_words.shape[0]
    assert n % (16 * lanes) == 0, f"message words {n} not a multiple of {16 * lanes}"
    grid = n // (16 * lanes)
    tile = 16 * lanes
    return pl.pallas_call(
        functools.partial(_kernel, lanes=lanes),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((8,), lambda i: (0,)),
            pl.BlockSpec((3,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.uint32),
        interpret=True,
    )(
        key.astype(jnp.uint32),
        nonce.astype(jnp.uint32),
        counter0.astype(jnp.uint32),
        msg_words.astype(jnp.uint32),
    )


def keystream_block0(key, nonce):
    """Keystream block with counter 0 (Poly1305 one-time-key generation),
    as (16,) u32 — computed with the same lane kernel at W=1 grid=1."""
    zero_msg = jnp.zeros((16,), jnp.uint32)
    ctr = jnp.zeros((1,), jnp.uint32)
    return pl.pallas_call(
        functools.partial(_kernel, lanes=1),
        grid=(1,),
        in_specs=[
            pl.BlockSpec((8,), lambda i: (0,)),
            pl.BlockSpec((3,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((16,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((16,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((16,), jnp.uint32),
        interpret=True,
    )(key.astype(jnp.uint32), nonce.astype(jnp.uint32), ctr, zero_msg)
