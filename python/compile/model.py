"""Layer-2 JAX model: the ChaCha20-Poly1305 AEAD record pipeline.

``seal_record`` is the compute graph the rust request path executes: it
calls the Layer-1 Pallas ChaCha kernel for the bulk cipher and keystream
block 0, and computes the Poly1305 MAC with 26-bit-limb arithmetic
(products fit u64; requires jax_enable_x64, set in aot.py / tests).

Record framing matches RFC 7539 §2.8 with empty AAD and whole-block
records: mac data = ct ‖ len(aad)=0 ‖ len(ct). The record length is fixed
at AOT time (RECORD_WORDS); the rust runtime chunks/pads byte streams.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import chacha

# 16 KiB records = 4096 u32 words = 256 ChaCha blocks.
RECORD_WORDS = 4096

_M26 = jnp.uint64(0x3FFFFFF)


def _clamp_r(k0, k1, k2, k3):
    """Poly1305 r-clamp on 4 u32 words."""
    return (
        k0 & jnp.uint32(0x0FFFFFFF),
        k1 & jnp.uint32(0x0FFFFFFC),
        k2 & jnp.uint32(0x0FFFFFFC),
        k3 & jnp.uint32(0x0FFFFFFC),
    )


def _limbs_from_words(m0, m1, m2, m3, hibit):
    """Split a 16-byte little-endian block (4 u32) into 5×26-bit limbs."""
    m0 = m0.astype(jnp.uint64)
    m1 = m1.astype(jnp.uint64)
    m2 = m2.astype(jnp.uint64)
    m3 = m3.astype(jnp.uint64)
    t0 = m0 & _M26
    t1 = ((m0 >> jnp.uint64(26)) | (m1 << jnp.uint64(6))) & _M26
    t2 = ((m1 >> jnp.uint64(20)) | (m2 << jnp.uint64(12))) & _M26
    t3 = ((m2 >> jnp.uint64(14)) | (m3 << jnp.uint64(18))) & _M26
    t4 = (m3 >> jnp.uint64(8)) | (jnp.uint64(hibit) << jnp.uint64(24))
    return jnp.stack([t0, t1, t2, t3, t4])


def _poly_mul_mod(h, r, s):
    """(h·r) mod 2^130−5 on 5×26-bit limbs. Max addend < 2^58, fits u64."""
    d0 = h[0] * r[0] + h[1] * s[4] + h[2] * s[3] + h[3] * s[2] + h[4] * s[1]
    d1 = h[0] * r[1] + h[1] * r[0] + h[2] * s[4] + h[3] * s[3] + h[4] * s[2]
    d2 = h[0] * r[2] + h[1] * r[1] + h[2] * r[0] + h[3] * s[4] + h[4] * s[3]
    d3 = h[0] * r[3] + h[1] * r[2] + h[2] * r[1] + h[3] * r[0] + h[4] * s[4]
    d4 = h[0] * r[4] + h[1] * r[3] + h[2] * r[2] + h[3] * r[1] + h[4] * r[0]
    # Carry chain.
    c = d0 >> jnp.uint64(26)
    d0 &= _M26
    d1 += c
    c = d1 >> jnp.uint64(26)
    d1 &= _M26
    d2 += c
    c = d2 >> jnp.uint64(26)
    d2 &= _M26
    d3 += c
    c = d3 >> jnp.uint64(26)
    d3 &= _M26
    d4 += c
    c = d4 >> jnp.uint64(26)
    d4 &= _M26
    d0 += c * jnp.uint64(5)
    c = d0 >> jnp.uint64(26)
    d0 &= _M26
    d1 += c
    return jnp.stack([d0, d1, d2, d3, d4])


def poly1305_tag(mac_words, otk_words):
    """Poly1305 over ``mac_words`` (u32, multiple of 4 = whole 16-byte
    blocks) under the 8-word one-time key. Returns the tag as 4 u32."""
    r = _clamp_r(otk_words[0], otk_words[1], otk_words[2], otk_words[3])
    r = [x.astype(jnp.uint64) for x in r]
    # 26-bit limbs of r.
    r_l = jnp.stack(
        [
            r[0] & _M26,
            ((r[0] >> jnp.uint64(26)) | (r[1] << jnp.uint64(6))) & _M26,
            ((r[1] >> jnp.uint64(20)) | (r[2] << jnp.uint64(12))) & _M26,
            ((r[2] >> jnp.uint64(14)) | (r[3] << jnp.uint64(18))) & _M26,
            r[3] >> jnp.uint64(8),
        ]
    )
    s_l = r_l * jnp.uint64(5)

    blocks = mac_words.reshape(-1, 4)

    def step(h, blk):
        t = _limbs_from_words(blk[0], blk[1], blk[2], blk[3], 1)
        h = _poly_mul_mod(h + t, r_l, s_l)
        return h, None

    h0 = jnp.zeros((5,), jnp.uint64)
    h, _ = jax.lax.scan(step, h0, blocks)

    # Full carry, then freeze: g = h + 5 − p; select g when h ≥ p.
    c = h[0] >> jnp.uint64(26)
    h = h.at[0].set(h[0] & _M26)
    h = h.at[1].add(c)
    c = h[1] >> jnp.uint64(26)
    h = h.at[1].set(h[1] & _M26)
    h = h.at[2].add(c)
    c = h[2] >> jnp.uint64(26)
    h = h.at[2].set(h[2] & _M26)
    h = h.at[3].add(c)
    c = h[3] >> jnp.uint64(26)
    h = h.at[3].set(h[3] & _M26)
    h = h.at[4].add(c)
    c = h[4] >> jnp.uint64(26)
    h = h.at[4].set(h[4] & _M26)
    h = h.at[0].add(c * jnp.uint64(5))
    c = h[0] >> jnp.uint64(26)
    h = h.at[0].set(h[0] & _M26)
    h = h.at[1].add(c)

    g0 = h[0] + jnp.uint64(5)
    c = g0 >> jnp.uint64(26)
    g0 &= _M26
    g1 = h[1] + c
    c = g1 >> jnp.uint64(26)
    g1 &= _M26
    g2 = h[2] + c
    c = g2 >> jnp.uint64(26)
    g2 &= _M26
    g3 = h[3] + c
    c = g3 >> jnp.uint64(26)
    g3 &= _M26
    g4 = h[4] + c
    over = g4 >> jnp.uint64(26)  # 1 iff h + 5 ≥ 2^130, i.e. h ≥ p
    g4 &= _M26
    sel = (over * jnp.uint64(0xFFFFFFFFFFFFFFFF)).astype(jnp.uint64)
    h0f = (g0 & sel) | (h[0] & ~sel)
    h1f = (g1 & sel) | (h[1] & ~sel)
    h2f = (g2 & sel) | (h[2] & ~sel)
    h3f = (g3 & sel) | (h[3] & ~sel)
    h4f = (g4 & sel) | (h[4] & ~sel)

    # Re-pack limbs to 4 u32 words.
    w0 = (h0f | (h1f << jnp.uint64(26))) & jnp.uint64(0xFFFFFFFF)
    w1 = ((h1f >> jnp.uint64(6)) | (h2f << jnp.uint64(20))) & jnp.uint64(0xFFFFFFFF)
    w2 = ((h2f >> jnp.uint64(12)) | (h3f << jnp.uint64(14))) & jnp.uint64(0xFFFFFFFF)
    w3 = ((h3f >> jnp.uint64(18)) | (h4f << jnp.uint64(8))) & jnp.uint64(0xFFFFFFFF)

    # tag = (h + s) mod 2^128, s = otk words 4..8.
    s0 = otk_words[4].astype(jnp.uint64)
    s1 = otk_words[5].astype(jnp.uint64)
    s2 = otk_words[6].astype(jnp.uint64)
    s3 = otk_words[7].astype(jnp.uint64)
    t0 = w0 + s0
    t1 = w1 + s1 + (t0 >> jnp.uint64(32))
    t2 = w2 + s2 + (t1 >> jnp.uint64(32))
    t3 = w3 + s3 + (t2 >> jnp.uint64(32))
    mask = jnp.uint64(0xFFFFFFFF)
    return jnp.stack([t0 & mask, t1 & mask, t2 & mask, t3 & mask]).astype(jnp.uint32)


def seal_record(key, nonce, msg_words, *, lanes: int = 16):
    """AEAD-seal one fixed-size record. Returns (ct_words, tag_words).

    * ``key``: (8,) u32 — 256-bit key.
    * ``nonce``: (3,) u32 — 96-bit nonce.
    * ``msg_words``: (RECORD_WORDS,) u32 — 16 KiB plaintext.
    """
    ct = chacha.chacha20_xor(
        key, nonce, jnp.ones((1,), jnp.uint32), msg_words, lanes=lanes
    )
    otk = chacha.keystream_block0(key, nonce)[:8]
    # Whole-block record + empty AAD: mac data = ct ‖ [0,0,len,0].
    ct_bytes = msg_words.shape[0] * 4
    length_block = jnp.array([0, 0, ct_bytes & 0xFFFFFFFF, 0], dtype=jnp.uint32)
    mac_words = jnp.concatenate([ct, length_block])
    tag = poly1305_tag(mac_words, otk)
    return ct, tag


def seal_record_fn(lanes: int):
    """The jit-able entry point lowered by aot.py for one lane width."""

    def fn(key, nonce, msg_words):
        return seal_record(key, nonce, msg_words, lanes=lanes)

    return fn
