"""Layer-1 correctness: Pallas ChaCha kernel vs the numpy reference and
the RFC 7539 test vectors; hypothesis sweeps over shapes and inputs."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import chacha, ref

# ---- RFC 7539 test vectors ------------------------------------------------

RFC_KEY = bytes(range(32))  # 00 01 02 … 1f
RFC_NONCE = bytes.fromhex("000000090000004a00000000")


def test_rfc_block_function():
    """RFC 7539 §2.3.2: keystream block, key 00..1f, counter 1."""
    key = ref.bytes_to_words(RFC_KEY)
    nonce = ref.bytes_to_words(RFC_NONCE)
    block = ref.chacha20_block(key, 1, nonce)
    expected = np.array(
        [
            0xE4E7F110, 0x15593BD1, 0x1FDD0F50, 0xC47120A3,
            0xC7F4D1C7, 0x0368C033, 0x9AAA2204, 0x4E6CD4C3,
            0x466482D2, 0x09AA9F07, 0x05D7C214, 0xA2028BD9,
            0xD19C12B5, 0xB94E16DE, 0xE883D0CB, 0x4E3C50A2,
        ],
        dtype=np.uint32,
    )
    np.testing.assert_array_equal(block, expected)


def test_rfc_encryption():
    """RFC 7539 §2.4.2: 'Ladies and Gentlemen…' under counter 1."""
    key = ref.bytes_to_words(RFC_KEY)
    nonce = ref.bytes_to_words(bytes.fromhex("000000000000004a00000000"))
    plaintext = (
        b"Ladies and Gentlemen of the class of '99: If I could offer you "
        b"only one tip for the future, sunscreen would be it."
    )
    padded = plaintext + bytes(-len(plaintext) % 64)
    ct = ref.words_to_bytes(ref.chacha20_xor(key, nonce, 1, ref.bytes_to_words(padded)))
    expected_prefix = bytes.fromhex(
        "6e2e359a2568f98041ba0728dd0d6981e97e7aec1d4360c20a27afccfd9fae0b"
    )
    assert ct[:32] == expected_prefix


def test_rfc_poly1305():
    """RFC 7539 §2.5.2: Poly1305 tag."""
    key = bytes.fromhex(
        "85d6be7857556d337f4452fe42d506a80103808afb0db2fd4abff6af4149f51b"
    )
    msg = b"Cryptographic Forum Research Group"
    tag = ref.poly1305_mac(msg, key)
    assert tag == bytes.fromhex("a8061dc1305136c6c22b8baf0c0127a9")


def test_rfc_poly1305_key_gen():
    """RFC 7539 §2.6.2: one-time key generation."""
    key = ref.bytes_to_words(bytes(range(0x80, 0xA0)))
    nonce = ref.bytes_to_words(bytes.fromhex("000000000001020304050607"))
    otk = ref.poly1305_key_gen(key, nonce)
    assert otk == bytes.fromhex(
        "8ad5a08b905f81cc815040274ab29471a833b637e3fd0da508dbb8e2fdd1a646"
    )


def test_rfc_aead_seal():
    """RFC 7539 §2.8.2: AEAD seal tag (with AAD)."""
    key = ref.bytes_to_words(bytes(range(0x80, 0xA0)))
    nonce = ref.bytes_to_words(bytes.fromhex("070000004041424344454647"))
    aad = bytes.fromhex("50515253c0c1c2c3c4c5c6c7")
    plaintext = (
        b"Ladies and Gentlemen of the class of '99: If I could offer you "
        b"only one tip for the future, sunscreen would be it."
    )
    ct, tag = ref.seal(key, nonce, plaintext, aad)
    assert ct[:16] == bytes.fromhex("d31a8d34648e60db7b86afbc53ef7ec2")
    assert tag == bytes.fromhex("1ae10b594f09e26a7e902ecbd0600691")
    # Round trip.
    assert ref.open_(key, nonce, ct, tag, aad) == plaintext


# ---- Pallas kernel vs reference -------------------------------------------


def rand_words(rng, n):
    return rng.integers(0, 2**32, size=n, dtype=np.uint32)


@pytest.mark.parametrize("lanes", [1, 4, 8, 16])
def test_kernel_matches_ref(lanes):
    rng = np.random.default_rng(7)
    key = rand_words(rng, 8)
    nonce = rand_words(rng, 3)
    n_words = 16 * lanes * 3  # 3 grid steps
    msg = rand_words(rng, n_words)
    got = np.asarray(
        chacha.chacha20_xor(
            jnp.asarray(key), jnp.asarray(nonce), jnp.ones((1,), jnp.uint32),
            jnp.asarray(msg), lanes=lanes,
        )
    )
    want = ref.chacha20_xor(key, nonce, 1, msg)
    np.testing.assert_array_equal(got, want)


def test_all_widths_agree():
    """The three SIMD-width variants must be bit-identical."""
    rng = np.random.default_rng(11)
    key = jnp.asarray(rand_words(rng, 8))
    nonce = jnp.asarray(rand_words(rng, 3))
    msg = jnp.asarray(rand_words(rng, 16 * 16 * 2))
    ctr = jnp.ones((1,), jnp.uint32)
    w4 = chacha.chacha20_xor(key, nonce, ctr, msg, lanes=4)
    w8 = chacha.chacha20_xor(key, nonce, ctr, msg, lanes=8)
    w16 = chacha.chacha20_xor(key, nonce, ctr, msg, lanes=16)
    np.testing.assert_array_equal(np.asarray(w4), np.asarray(w8))
    np.testing.assert_array_equal(np.asarray(w8), np.asarray(w16))


def test_keystream_block0_matches_ref():
    rng = np.random.default_rng(13)
    key = rand_words(rng, 8)
    nonce = rand_words(rng, 3)
    got = np.asarray(chacha.keystream_block0(jnp.asarray(key), jnp.asarray(nonce)))
    want = ref.chacha20_block(key, 0, nonce)
    np.testing.assert_array_equal(got, want)


def test_xor_roundtrip():
    rng = np.random.default_rng(17)
    key = jnp.asarray(rand_words(rng, 8))
    nonce = jnp.asarray(rand_words(rng, 3))
    msg = jnp.asarray(rand_words(rng, 16 * 16))
    ctr = jnp.ones((1,), jnp.uint32)
    ct = chacha.chacha20_xor(key, nonce, ctr, msg, lanes=16)
    pt = chacha.chacha20_xor(key, nonce, ctr, ct, lanes=16)
    np.testing.assert_array_equal(np.asarray(pt), np.asarray(msg))


# ---- hypothesis sweeps -----------------------------------------------------

word = st.integers(min_value=0, max_value=2**32 - 1)


@settings(max_examples=20, deadline=None)
@given(
    key=st.lists(word, min_size=8, max_size=8),
    nonce=st.lists(word, min_size=3, max_size=3),
    counter=st.integers(min_value=0, max_value=2**31),
    steps=st.integers(min_value=1, max_value=4),
    lanes=st.sampled_from([4, 8, 16]),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_kernel_vs_ref_hypothesis(key, nonce, counter, steps, lanes, seed):
    rng = np.random.default_rng(seed)
    key = np.array(key, dtype=np.uint32)
    nonce = np.array(nonce, dtype=np.uint32)
    msg = rand_words(rng, 16 * lanes * steps)
    got = np.asarray(
        chacha.chacha20_xor(
            jnp.asarray(key),
            jnp.asarray(nonce),
            jnp.array([counter], dtype=jnp.uint32),
            jnp.asarray(msg),
            lanes=lanes,
        )
    )
    want = ref.chacha20_xor(key, nonce, counter, msg)
    np.testing.assert_array_equal(got, want)


@settings(max_examples=20, deadline=None)
@given(
    n_blocks=st.integers(min_value=1, max_value=12),
    key=st.binary(min_size=32, max_size=32),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_poly1305_bignum_vs_limb_hypothesis(n_blocks, key, seed):
    """Cross-check two independent Poly1305 implementations: the python
    bignum reference against the JAX 26-bit-limb arithmetic (whole-block
    messages, which is what the AOT model MACs).

    (Note: 'flipping a key bit changes the tag' is NOT a theorem — the
    final mod 2^128 truncation admits collisions, and hypothesis finds
    them — so equivalence against an independent algorithm is the honest
    property.)"""
    import jax.numpy as jnp

    from compile import model

    rng = np.random.default_rng(seed)
    data = rng.bytes(16 * n_blocks)
    want = ref.poly1305_mac(data, key)
    got = model.poly1305_tag(
        jnp.asarray(ref.bytes_to_words(data)),
        jnp.asarray(ref.bytes_to_words(key)),
    )
    assert ref.words_to_bytes(np.asarray(got)) == want
