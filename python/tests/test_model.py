"""Layer-2 correctness: the JAX seal_record model (Pallas ChaCha +
limb-arithmetic Poly1305) against the numpy/bignum reference."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import model
from compile.kernels import ref


def rand_words(rng, n):
    return rng.integers(0, 2**32, size=n, dtype=np.uint32)


def seal_ref_words(key, nonce, msg_words):
    """Reference seal on whole-word records; returns (ct_words, tag_words)."""
    pt = ref.words_to_bytes(msg_words)
    ct, tag = ref.seal(key, nonce, pt)
    return ref.bytes_to_words(ct), ref.bytes_to_words(tag)


@pytest.mark.parametrize("lanes", [4, 8, 16])
def test_seal_record_matches_ref(lanes):
    rng = np.random.default_rng(23)
    key = rand_words(rng, 8)
    nonce = rand_words(rng, 3)
    msg = rand_words(rng, model.RECORD_WORDS)
    ct, tag = model.seal_record(
        jnp.asarray(key), jnp.asarray(nonce), jnp.asarray(msg), lanes=lanes
    )
    want_ct, want_tag = seal_ref_words(key, nonce, msg)
    np.testing.assert_array_equal(np.asarray(ct), want_ct)
    np.testing.assert_array_equal(np.asarray(tag), want_tag)


def test_output_shapes_and_dtypes():
    rng = np.random.default_rng(29)
    key = jnp.asarray(rand_words(rng, 8))
    nonce = jnp.asarray(rand_words(rng, 3))
    msg = jnp.asarray(rand_words(rng, model.RECORD_WORDS))
    ct, tag = model.seal_record(key, nonce, msg)
    assert ct.shape == (model.RECORD_WORDS,)
    assert tag.shape == (4,)
    assert ct.dtype == jnp.uint32
    assert tag.dtype == jnp.uint32


def test_poly1305_tag_against_rfc_vector():
    """Drive poly1305_tag directly with the RFC §2.5.2 one-time key on a
    whole-block message (pad the RFC message to 48 bytes with the length
    framing handled manually)."""
    otk_bytes = bytes.fromhex(
        "85d6be7857556d337f4452fe42d506a80103808afb0db2fd4abff6af4149f51b"
    )
    # Whole 16-byte blocks only: use a 32-byte slice of the RFC message.
    msg = b"Cryptographic Forum Research Gro"
    want = ref.poly1305_mac(msg, otk_bytes)
    got = model.poly1305_tag(
        jnp.asarray(ref.bytes_to_words(msg)),
        jnp.asarray(ref.bytes_to_words(otk_bytes)),
    )
    assert ref.words_to_bytes(np.asarray(got)) == want


def test_tag_rejects_bitflip():
    rng = np.random.default_rng(31)
    key = rand_words(rng, 8)
    nonce = rand_words(rng, 3)
    msg = rand_words(rng, model.RECORD_WORDS)
    _, tag = model.seal_record(jnp.asarray(key), jnp.asarray(nonce), jnp.asarray(msg))
    flipped = msg.copy()
    flipped[0] ^= 1
    _, tag2 = model.seal_record(
        jnp.asarray(key), jnp.asarray(nonce), jnp.asarray(flipped)
    )
    assert not np.array_equal(np.asarray(tag), np.asarray(tag2))


word = st.integers(min_value=0, max_value=2**32 - 1)


@settings(max_examples=8, deadline=None)
@given(
    key=st.lists(word, min_size=8, max_size=8),
    nonce=st.lists(word, min_size=3, max_size=3),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_seal_record_hypothesis(key, nonce, seed):
    rng = np.random.default_rng(seed)
    key = np.array(key, dtype=np.uint32)
    nonce = np.array(nonce, dtype=np.uint32)
    msg = rand_words(rng, model.RECORD_WORDS)
    ct, tag = model.seal_record(
        jnp.asarray(key), jnp.asarray(nonce), jnp.asarray(msg), lanes=16
    )
    want_ct, want_tag = seal_ref_words(key, nonce, msg)
    np.testing.assert_array_equal(np.asarray(ct), want_ct)
    np.testing.assert_array_equal(np.asarray(tag), want_tag)


def test_poly1305_many_random_messages():
    """Limb arithmetic edge cases: random one-time keys and messages,
    including near-modulus accumulator values."""
    rng = np.random.default_rng(37)
    for _ in range(25):
        otk = rng.bytes(32)
        n_blocks = int(rng.integers(1, 8))
        msg = rng.bytes(16 * n_blocks)
        want = ref.poly1305_mac(msg, otk)
        got = model.poly1305_tag(
            jnp.asarray(ref.bytes_to_words(msg)),
            jnp.asarray(ref.bytes_to_words(otk)),
        )
        assert ref.words_to_bytes(np.asarray(got)) == want


def test_poly1305_all_ones_message():
    """0xFF…FF blocks push the accumulator toward the modulus — the freeze
    path must be exercised."""
    otk = bytes.fromhex("ff" * 16 + "00" * 16)  # r = clamp(ff..) , s = 0
    msg = b"\xff" * 64
    want = ref.poly1305_mac(msg, otk)
    got = model.poly1305_tag(
        jnp.asarray(ref.bytes_to_words(msg)), jnp.asarray(ref.bytes_to_words(otk))
    )
    assert ref.words_to_bytes(np.asarray(got)) == want
