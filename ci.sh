#!/usr/bin/env bash
# Tier-1 CI: release build, tests, docs with warnings denied, and a link
# check over the markdown docs. Run from the repo root.
set -euo pipefail
cd "$(dirname "$0")"

echo "== cargo build --release =="
cargo build --release

echo "== cargo build --release --benches --examples =="
cargo build --release --benches --examples

echo "== cargo test -q =="
cargo test -q

echo "== cargo doc --no-deps (-D warnings) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

echo "== markdown link check (local links in README.md, docs/, rust/tests/) =="
fail=0
for f in README.md docs/*.md rust/tests/README.md; do
  # Extract local markdown link targets (anchors stripped) and resolve
  # them the way a renderer would: relative to the file's directory only.
  while IFS= read -r target; do
    [ -z "$target" ] && continue
    dir=$(dirname "$f")
    if [ ! -e "$dir/$target" ]; then
      echo "BROKEN LINK in $f: $target"
      fail=1
    fi
  done < <(grep -oE '\]\(([^)]+)\)' "$f" 2>/dev/null \
             | sed -E 's/^\]\(//; s/\)$//; s/#.*$//' \
             | grep -vE '^[a-z]+://' | grep -v '^$' || true)
done
# Files referenced by backtick path convention in README/ARCHITECTURE.
for p in docs/ARCHITECTURE.md rust/tests/README.md configs/dual_socket.toml \
         rust/src/scenario/mod.rs rust/tests/scenario_matrix.rs ci.sh; do
  if [ ! -e "$p" ]; then
    echo "MISSING referenced file: $p"
    fail=1
  fi
done
if [ "$fail" -ne 0 ]; then
  echo "link check FAILED"
  exit 1
fi
echo "link check OK"

echo "ci.sh: all green"
