#!/usr/bin/env bash
# Tier-1 CI: release build, the test suites as separate named + timed
# steps, docs with warnings denied, and a link check over the markdown
# docs. Run from the repo root.
#
# Without a Rust toolchain the cargo-backed steps cannot run; instead of
# hard-failing on the first missing binary, each one is reported as a
# named SKIP and summarized at the end, and the toolchain-free checks
# (golden snapshots present, markdown links, referenced files) still
# gate. The first toolchain-equipped run then executes the full matrix
# and writes the BENCH_10.json perf record.
set -euo pipefail
cd "$(dirname "$0")"

HAVE_CARGO=1
command -v cargo >/dev/null 2>&1 || HAVE_CARGO=0
SKIPPED=()

# Run a named step and report its wall-clock duration.
step() {
  local name="$1"; shift
  echo "== ${name} =="
  local t0 t1
  t0=$(date +%s)
  "$@"
  t1=$(date +%s)
  echo "-- ${name}: $((t1 - t0))s"
}

# Run a named step that needs the Rust toolchain, or record a named SKIP.
cargo_step() {
  local name="$1"; shift
  if [ "$HAVE_CARGO" -eq 1 ]; then
    step "$name" "$@"
  else
    echo "== ${name} =="
    echo "SKIP: cargo not on PATH — ${name} not run"
    SKIPPED+=("$name")
  fi
}

cargo_step "cargo build --release" cargo build --release
cargo_step "cargo build --release --benches --examples" \
  cargo build --release --benches --examples

# Unit tests (lib + bin) and doctests.
cargo_step "unit tests" cargo test -q --lib --bins
cargo_step "doctests" cargo test -q --doc

# The event queue's past-dated-schedule contract differs by profile
# (debug: panic; release: documented clamp + counter). The debug side
# runs in the normal unit pass above; this step compiles the lib tests
# under --release so `past_scheduling_clamps_in_release` actually runs.
cargo_step "release-profile queue clamp tests" \
  cargo test --release -q --lib sim::queue

# Golden snapshots must exist before the suites run: a fresh checkout
# missing one would otherwise "pass" only via UPDATE_GOLDEN, and the
# fleet tables' formatting contract would be unpinned.
check_goldens() {
  local missing=0
  for g in matrix_report tail_report fleet_report fleetvar_report \
           energy_report energydelay_report tpc_report runtimespec_report \
           hier_report fleetscale_report hybrid_report hybridspec_report \
           fault_report faulttol_report; do
    if [ ! -f "rust/tests/golden/${g}.txt" ]; then
      echo "MISSING golden snapshot: rust/tests/golden/${g}.txt"
      missing=1
    fi
  done
  [ "$missing" -eq 0 ]
}
step "golden snapshots present" check_goldens

# Integration suites, one named step each (see rust/tests/README.md).
# The list is derived from Cargo.toml's [[test]] entries so a new suite
# cannot be registered there yet silently skipped here;
# runtime_roundtrip runs separately below with its SKIP guard.
suites=$(grep -A1 '^\[\[test\]\]' Cargo.toml | sed -n 's/^name = "\(.*\)"$/\1/p')
for suite in $suites; do
  [ "$suite" = "runtime_roundtrip" ] && continue
  cargo_step "suite: ${suite}" cargo test -q --test "${suite}"
done

# runtime_roundtrip skips by design without the AOT artifacts, but a
# SKIP that does not name the missing artifacts directory means the
# guard regressed (wrong env var, silent mis-skip) — fail on it.
run_runtime_roundtrip() {
  local out
  out=$(cargo test -q --test runtime_roundtrip -- --nocapture 2>&1) || {
    echo "$out"
    return 1
  }
  echo "$out"
  # Per-line check: ANY SKIP line that does not name the artifacts
  # directory fails, even when another test's notice is well-formed.
  if echo "$out" | grep "SKIP" | grep -qv "SKIP: artifacts directory"; then
    echo "runtime_roundtrip printed SKIP without naming the artifacts directory"
    return 1
  fi
}
cargo_step "suite: runtime_roundtrip (SKIP must name artifacts dir)" run_runtime_roundtrip

# Bench smoke: one quick fast-vs-baseline pass (the executor,
# closed-loop hier, and incremental-forking scenarios ride along, so
# `LoadMode::Executor`, the hierarchical balancer, and checkpoint
# forking are covered). `avxfreq bench` exits non-zero if the two legs'
# outputs diverge (the equivalence gate — for the `chaos` scenario that
# gate is faults-off ≡ pre-PR fingerprint) and writes the BENCH_10.json
# perf-trajectory record; the speedup itself is informational here —
# wall-clock on a loaded CI machine is noise, so compare ratios across
# runs, not absolutes (rust/tests/README.md).
run_bench_quick() {
  cargo run --release --quiet -- bench --quick
  if [ ! -f BENCH_10.json ]; then
    echo "bench did not write BENCH_10.json"
    return 1
  fi
  if grep -q '"outputs_identical": false' BENCH_10.json; then
    echo "BENCH_10.json records an output divergence"
    return 1
  fi
  if ! grep -q '"warmup_ns_reused":' BENCH_10.json; then
    echo "BENCH_10.json is missing the warmup_ns_reused field"
    return 1
  fi
  return 0
}
cargo_step "bench --quick (equivalence gate + BENCH_10.json)" run_bench_quick

cargo_step "cargo doc --no-deps (-D warnings)" \
  env RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

echo "== markdown link check (local links in README.md, docs/, rust/tests/) =="
fail=0
for f in README.md docs/*.md rust/tests/README.md; do
  # Extract local markdown link targets (anchors stripped) and resolve
  # them the way a renderer would: relative to the file's directory only.
  while IFS= read -r target; do
    [ -z "$target" ] && continue
    dir=$(dirname "$f")
    if [ ! -e "$dir/$target" ]; then
      echo "BROKEN LINK in $f: $target"
      fail=1
    fi
  done < <(grep -oE '\]\(([^)]+)\)' "$f" 2>/dev/null \
             | sed -E 's/^\]\(//; s/\)$//; s/#.*$//' \
             | grep -vE '^[a-z]+://' | grep -v '^$' || true)
done
# Files referenced by backtick path convention in README/ARCHITECTURE.
for p in docs/ARCHITECTURE.md rust/tests/README.md configs/dual_socket.toml \
         configs/bursty_slo.toml configs/fleet_slo.toml configs/fleet_closed.toml \
         rust/src/scenario/mod.rs \
         rust/src/traffic/mod.rs rust/src/traffic/arrival.rs \
         rust/src/traffic/lifecycle.rs rust/tests/scenario_matrix.rs \
         rust/tests/traffic.rs rust/tests/golden_report.rs \
         rust/tests/golden/matrix_report.txt rust/tests/golden/tail_report.txt \
         rust/src/fleet/mod.rs rust/src/fleet/router.rs rust/src/fleet/cluster.rs \
         rust/src/fleet/hierarchy.rs rust/src/fleet/balancer.rs \
         rust/src/repro/fleetvar.rs rust/src/repro/fleetscale.rs \
         rust/tests/fleet.rs rust/tests/hierfleet.rs \
         rust/tests/golden/fleet_report.txt rust/tests/golden/fleetvar_report.txt \
         rust/tests/golden/hier_report.txt rust/tests/golden/fleetscale_report.txt \
         configs/energy.toml rust/src/cpu/governor.rs rust/src/cpu/power.rs \
         rust/src/repro/energydelay.rs rust/tests/power.rs \
         rust/tests/golden/energy_report.txt rust/tests/golden/energydelay_report.txt \
         rust/src/bench/mod.rs rust/src/sim/queue.rs rust/src/cpu/ipc.rs \
         rust/tests/perf_equiv.rs \
         configs/tpc.toml rust/src/tpc/mod.rs rust/src/tpc/placement.rs \
         rust/src/tpc/queue.rs rust/src/tpc/reactor.rs rust/src/tpc/waker.rs \
         rust/src/repro/runtimespec.rs rust/tests/tpc.rs \
         rust/tests/golden/tpc_report.txt rust/tests/golden/runtimespec_report.txt \
         configs/hybrid.toml rust/src/cpu/topology.rs rust/src/repro/hybridspec.rs \
         rust/tests/hybrid.rs \
         rust/tests/golden/hybrid_report.txt rust/tests/golden/hybridspec_report.txt \
         rust/tests/incremental.rs rust/src/workload/webserver.rs \
         rust/src/sched/machine.rs \
         configs/chaos.toml rust/src/faults/mod.rs rust/src/repro/faulttol.rs \
         rust/tests/faults.rs \
         rust/tests/golden/fault_report.txt rust/tests/golden/faulttol_report.txt \
         ci.sh; do
  if [ ! -e "$p" ]; then
    echo "MISSING referenced file: $p"
    fail=1
  fi
done
if [ "$fail" -ne 0 ]; then
  echo "link check FAILED"
  exit 1
fi
echo "link check OK"

if [ "${#SKIPPED[@]}" -gt 0 ]; then
  echo "== SKIP summary =="
  for s in "${SKIPPED[@]}"; do
    echo "SKIPPED: ${s}"
  done
  echo "ci.sh: ${#SKIPPED[@]} cargo-backed steps skipped (no Rust toolchain); toolchain-free checks green"
else
  echo "ci.sh: all green"
fi
