//! Offline minimal `flate2` surface.
//!
//! Provides [`write::DeflateEncoder`] emitting a *valid raw-deflate
//! stream* built from stored (BTYPE=00, uncompressed) blocks — any
//! inflate implementation decodes it, but no compression is performed.
//! That is sufficient here: the example server uses deflate only as a
//! scalar-work stand-in for brotli, and nothing in the repo inflates the
//! result. The compression level is accepted and ignored.

/// Compression level (accepted for API compatibility, ignored).
#[derive(Clone, Copy, Debug)]
pub struct Compression(pub u32);

impl Compression {
    /// Create a compression level (0–9 in the real crate).
    pub fn new(level: u32) -> Compression {
        Compression(level)
    }
}

pub mod write {
    //! Writer-based encoders.

    use std::io::{self, Write};

    /// Raw-deflate encoder writing stored blocks to the inner writer on
    /// [`DeflateEncoder::finish`].
    pub struct DeflateEncoder<W: Write> {
        inner: W,
        buf: Vec<u8>,
    }

    impl<W: Write> DeflateEncoder<W> {
        /// Wrap `inner`; the level is ignored (stored blocks only).
        pub fn new(inner: W, _level: super::Compression) -> Self {
            DeflateEncoder { inner, buf: Vec::new() }
        }

        /// Emit the deflate stream and return the inner writer.
        pub fn finish(mut self) -> io::Result<W> {
            // Stored blocks: 1-byte header (BFINAL | BTYPE=00), LEN,
            // NLEN (ones' complement), then the raw bytes. Max LEN is
            // 65535 per block; an empty input still needs one final
            // empty block to form a valid stream.
            let chunks: Vec<&[u8]> = if self.buf.is_empty() {
                vec![&[][..]]
            } else {
                self.buf.chunks(65535).collect()
            };
            let last = chunks.len() - 1;
            for (i, chunk) in chunks.iter().enumerate() {
                let bfinal = u8::from(i == last);
                let len = chunk.len() as u16;
                self.inner.write_all(&[bfinal])?;
                self.inner.write_all(&len.to_le_bytes())?;
                self.inner.write_all(&(!len).to_le_bytes())?;
                self.inner.write_all(chunk)?;
            }
            self.inner.flush()?;
            Ok(self.inner)
        }
    }

    impl<W: Write> Write for DeflateEncoder<W> {
        fn write(&mut self, data: &[u8]) -> io::Result<usize> {
            self.buf.extend_from_slice(data);
            Ok(data.len())
        }

        fn flush(&mut self) -> io::Result<()> {
            Ok(())
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        fn inflate_stored(stream: &[u8]) -> Vec<u8> {
            // Minimal decoder for stored-block-only streams.
            let mut out = Vec::new();
            let mut i = 0;
            loop {
                let hdr = stream[i];
                assert_eq!(hdr & 0b110, 0, "stored blocks only");
                let len = u16::from_le_bytes([stream[i + 1], stream[i + 2]]) as usize;
                let nlen = u16::from_le_bytes([stream[i + 3], stream[i + 4]]);
                assert_eq!(!(len as u16), nlen, "LEN/NLEN mismatch");
                out.extend_from_slice(&stream[i + 5..i + 5 + len]);
                i += 5 + len;
                if hdr & 1 == 1 {
                    break;
                }
            }
            assert_eq!(i, stream.len());
            out
        }

        #[test]
        fn roundtrip_small() {
            let mut enc = DeflateEncoder::new(Vec::new(), crate::Compression::new(4));
            enc.write_all(b"hello deflate").unwrap();
            let stream = enc.finish().unwrap();
            assert_eq!(inflate_stored(&stream), b"hello deflate");
        }

        #[test]
        fn roundtrip_multi_block() {
            let data: Vec<u8> = (0..200_000u32).map(|i| (i % 251) as u8).collect();
            let mut enc = DeflateEncoder::new(Vec::new(), crate::Compression::new(1));
            enc.write_all(&data).unwrap();
            let stream = enc.finish().unwrap();
            assert_eq!(inflate_stored(&stream), data);
        }

        #[test]
        fn empty_input_valid_stream() {
            let enc = DeflateEncoder::new(Vec::new(), crate::Compression::new(4));
            let stream = enc.finish().unwrap();
            assert_eq!(inflate_stored(&stream), Vec::<u8>::new());
        }
    }
}
