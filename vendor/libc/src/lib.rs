//! Offline minimal `libc` surface.
//!
//! The runtime server only needs CPU-affinity pinning and the online-CPU
//! count, so this vendored crate declares exactly those two glibc entry
//! points plus the `cpu_set_t` plumbing. Layout matches glibc on Linux
//! (`cpu_set_t` is a 1024-bit mask, 128 bytes).

#![allow(non_camel_case_types, non_snake_case)]

pub type c_int = i32;
pub type c_long = i64;

/// glibc `cpu_set_t`: 1024 CPU bits as 16 × u64.
pub type cpu_set_t = [u64; 16];

/// `sysconf` selector for the number of online processors (Linux).
pub const _SC_NPROCESSORS_ONLN: c_int = 84;

/// Set `cpu`'s bit in the mask (out-of-range bits are ignored, matching
/// the glibc macro's defined behaviour for CPU_SETSIZE overflow).
pub unsafe fn CPU_SET(cpu: usize, set: &mut cpu_set_t) {
    if cpu < 1024 {
        set[cpu / 64] |= 1u64 << (cpu % 64);
    }
}

extern "C" {
    /// Bind thread/process `pid` (0 = calling thread) to the mask.
    pub fn sched_setaffinity(pid: c_int, cpusetsize: usize, mask: *const cpu_set_t) -> c_int;
    /// Query a system configuration value.
    pub fn sysconf(name: c_int) -> c_long;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_set_bit_layout() {
        let mut set: cpu_set_t = [0; 16];
        unsafe {
            CPU_SET(0, &mut set);
            CPU_SET(65, &mut set);
            CPU_SET(4096, &mut set); // ignored, no panic
        }
        assert_eq!(set[0], 1);
        assert_eq!(set[1], 2);
    }

    #[test]
    fn sysconf_reports_cpus() {
        let n = unsafe { sysconf(_SC_NPROCESSORS_ONLN) };
        assert!(n >= 1, "got {n}");
    }
}
