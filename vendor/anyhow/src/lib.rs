//! Minimal offline re-implementation of the `anyhow` API surface this
//! repository uses: [`Error`], [`Result`], the [`Context`] trait, and the
//! `anyhow!` / `bail!` / `ensure!` macros.
//!
//! Semantics match upstream for the subset implemented: any
//! `std::error::Error + Send + Sync + 'static` converts into [`Error`]
//! via `?`, contexts stack (most recent first), `{}` prints the
//! outermost message and `{:#}` prints the whole chain separated by
//! `": "`.

use std::error::Error as StdError;
use std::fmt;

/// Dynamic error with a chain of context messages.
///
/// The chain always holds at least one entry; entry 0 is the outermost
/// (most recently attached) context and the last entry is the message of
/// the original error.
pub struct Error {
    chain: Vec<String>,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

/// `anyhow::Result<T>` — `std::result::Result` defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Create an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()], source: None }
    }

    /// Wrap a standard error, keeping it as the source.
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Self {
        Error { chain: vec![error.to_string()], source: Some(Box::new(error)) }
    }

    /// Attach an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// Iterate the message chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The root (innermost) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — full chain, outermost first.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

// The blanket conversion `?` relies on. `Error` itself deliberately does
// not implement `std::error::Error`, so this does not overlap with the
// reflexive `From<T> for T`.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Self {
        Error::new(error)
    }
}

/// Extension trait adding `.context(...)` / `.with_context(...)` to
/// `Result` and `Option`.
pub trait Context<T, E> {
    /// Wrap the error value with additional context.
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    /// Wrap the error value with lazily evaluated context.
    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error>;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::new(e).context(context))
    }
    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.map_err(|e| Error::new(e).context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }
    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn question_mark_converts() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(f().is_err());
    }

    #[test]
    fn context_stacks_outermost_first() {
        let e: Result<(), std::io::Error> = Err(io_err());
        let e = e.context("reading manifest").unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: missing file");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("nothing there").unwrap_err();
        assert_eq!(e.root_cause(), "nothing there");
    }

    #[test]
    fn macros_work() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert!(f(2).is_ok());
        assert!(f(3).is_err());
        assert!(f(11).is_err());
        let e = anyhow!("custom {}", 42);
        assert_eq!(format!("{e}"), "custom 42");
    }
}
