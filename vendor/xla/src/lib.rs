//! Offline stub of the `xla` PJRT bindings.
//!
//! The real crate links the PJRT C API and is only present in
//! environments with the XLA toolchain installed. This stub keeps the
//! `runtime` module compiling everywhere: every entry point that would
//! touch the backend returns an [`Error`] explaining that the stub is in
//! use, starting with [`PjRtClient::cpu`], so `avxfreq serve` /
//! `avxfreq calibrate` fail with a clear message instead of a link
//! error. Tests that need artifacts already skip when `artifacts/` is
//! absent, which is always the case without the real toolchain.

use std::fmt;

/// Error type mirroring the real bindings' failure reporting.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

/// Result alias used by all stub entry points.
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable() -> Error {
    Error(
        "PJRT backend unavailable: built against the offline `xla` stub (vendor/xla); \
         install the real xla bindings to execute AOT artifacts"
            .to_string(),
    )
}

/// PJRT client handle (stub).
pub struct PjRtClient;

impl PjRtClient {
    /// The real binding constructs a CPU PJRT client; the stub always fails.
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable())
    }

    /// Platform name of the backing device.
    pub fn platform_name(&self) -> String {
        "offline-stub".to_string()
    }

    /// Compile a computation for this client's device.
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parse HLO text from a file.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable())
    }
}

/// An XLA computation wrapping an HLO module (stub).
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a parsed module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// A compiled executable (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute with the given inputs; returns per-device output buffers.
    pub fn execute<L>(&self, _inputs: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

/// A device buffer holding one execution output (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Copy the buffer to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

/// A host-side literal value (stub).
pub struct Literal;

impl Literal {
    /// Build a rank-1 literal from a slice.
    pub fn vec1<T: Copy>(_values: &[T]) -> Literal {
        Literal
    }

    /// Destructure a 2-tuple literal.
    pub fn to_tuple2(self) -> Result<(Literal, Literal)> {
        Err(unavailable())
    }

    /// Copy out as a typed vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }
}
