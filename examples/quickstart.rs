//! Quickstart: simulate the paper's headline experiment in ~30 lines.
//!
//! Runs the nginx/OpenSSL web-server scenario twice — unmodified
//! scheduler vs core specialization — with AVX-512 crypto, and prints
//! the throughput and frequency recovery.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use avxfreq::sched::PolicyKind;
use avxfreq::sim::{MS, SEC};
use avxfreq::workload::crypto::Isa;
use avxfreq::workload::webserver::{run_webserver, WebCfg};

fn main() {
    let mut runs = Vec::new();
    for (name, policy) in [
        ("unmodified MuQSS", PolicyKind::Unmodified),
        ("core specialization (2 AVX cores)", PolicyKind::CoreSpec { avx_cores: 2 }),
    ] {
        let mut cfg = WebCfg::paper_default(Isa::Avx512, policy);
        cfg.warmup = 500 * MS;
        cfg.measure = 2 * SEC;
        println!("running {name}…");
        let run = run_webserver(&cfg);
        println!(
            "  throughput {:>6.0} req/s | avg busy freq {:.3} GHz | p99 {:.0} µs | {} type changes/s",
            run.throughput_rps, run.avg_ghz, run.tail.p99_us, run.type_changes_per_sec as u64
        );
        runs.push(run);
    }
    let gain = (runs[1].throughput_rps / runs[0].throughput_rps - 1.0) * 100.0;
    println!(
        "\ncore specialization recovers {gain:+.1}% throughput by confining the \
         AVX-512-induced frequency drop to 2 of 12 cores (paper §4: −11.2% → −3.2%)."
    );
}
