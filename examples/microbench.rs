//! §4.3 migration-overhead microbenchmark, runnable standalone.
//!
//! Sweeps the loop length (→ task-type-change rate) and prints the Fig 7
//! series: overhead % and cost per AVX↔scalar switch pair.
//!
//! ```sh
//! cargo run --release --example microbench [-- --full]
//! ```

use avxfreq::util::args::Args;
use avxfreq::workload::microbench::overhead_point;

fn main() {
    let args = Args::from_env();
    let lengths: &[u64] = if args.flag("full") {
        &[8_000_000, 4_000_000, 2_000_000, 1_000_000, 500_000, 250_000, 120_000, 60_000, 30_000]
    } else {
        &[2_000_000, 500_000, 120_000]
    };
    println!("26 threads on 12 cores, 5% of each loop marked as AVX (paper §4.3)\n");
    println!("{:>12} {:>16} {:>11} {:>18}", "loop insns", "type changes/s", "overhead %", "ns / switch pair");
    for &len in lengths {
        let p = overhead_point(len);
        println!(
            "{:>12} {:>16.0} {:>11.2} {:>18.0}",
            len, p.type_changes_per_sec, p.overhead_pct, p.ns_per_switch_pair
        );
    }
    println!("\npaper: 400–500 ns per switch pair; <3% overhead at 100k changes/s.");
    println!("the web-server scenario performs ~55-65k type changes/s — overhead well under 1%.");
}
