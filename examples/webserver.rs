//! End-to-end driver: every layer of the stack on a real workload.
//!
//! 1. Loads the AOT-compiled ChaCha20-Poly1305 HLO artifacts (L1 Pallas
//!    kernel + L2 JAX model) into the PJRT runtime.
//! 2. Starts the record-encrypting TCP server with the crypto confined to
//!    a pinned worker pool (user-level core specialization).
//! 3. Runs a client that fetches pages, **authenticates and decrypts
//!    every record** with the independent Rust AEAD implementation, and
//!    reports latency/throughput.
//! 4. Cross-checks the served bytes against the expected page content.
//!
//! ```sh
//! make artifacts && cargo run --release --example webserver
//! ```

use avxfreq::runtime::server::{self, ServeStats};
use avxfreq::runtime::Width;
use std::sync::Arc;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let artifacts = std::env::var("AVXFREQ_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if !std::path::Path::new(&artifacts).join("manifest.txt").exists() {
        anyhow::bail!("artifacts not found in `{artifacts}` — run `make artifacts` first");
    }

    let n_requests = 24u64;
    let page_bytes = 96 * 1024u32;
    let stats = Arc::new(ServeStats::default());

    // Server on an ephemeral port, in a background thread.
    let (port_tx, port_rx) = std::sync::mpsc::channel();
    let stats_srv = stats.clone();
    let artifacts_srv = artifacts.clone();
    let server = std::thread::spawn(move || {
        // Bind first on port 0 by asking serve() to report the bound port.
        // serve() blocks until max_requests connections are handled.
        let listener_port = 0u16;
        let res = server::serve_with_port_callback(
            &artifacts_srv,
            listener_port,
            Width::W16,
            2,
            true,
            n_requests,
            stats_srv,
            move |p| {
                let _ = port_tx.send(p);
            },
        );
        if let Err(e) = res {
            eprintln!("[server] {e:#}");
        }
    });
    let port = port_rx.recv_timeout(std::time::Duration::from_secs(120))?;
    let addr = format!("127.0.0.1:{port}");
    println!("server up at {addr}; fetching {n_requests} pages of {page_bytes} B…");

    // Client: fetch, verify, time.
    let expected = server::compress(&server::synth_page(page_bytes as usize))?;
    let mut latencies_ms = Vec::new();
    let t0 = Instant::now();
    for i in 0..n_requests {
        let t = Instant::now();
        let body = server::fetch(&addr, page_bytes)?;
        let ms = t.elapsed().as_secs_f64() * 1e3;
        latencies_ms.push(ms);
        anyhow::ensure!(body == expected, "request {i}: payload mismatch after decrypt");
    }
    let total_s = t0.elapsed().as_secs_f64();
    server.join().ok();

    latencies_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p = |q: f64| latencies_ms[((q * (latencies_ms.len() - 1) as f64) as usize).min(latencies_ms.len() - 1)];
    println!("\nall {n_requests} responses decrypted + authenticated against the Rust AEAD oracle ✓");
    println!(
        "throughput: {:.1} req/s | latency p50 {:.1} ms, p90 {:.1} ms, max {:.1} ms",
        n_requests as f64 / total_s,
        p(0.5),
        p(0.9),
        p(1.0),
    );
    println!(
        "records sealed on the PJRT crypto pool: {} ({} bytes)",
        stats.records.load(std::sync::atomic::Ordering::Relaxed),
        stats.bytes_sealed.load(std::sync::atomic::Ordering::Relaxed),
    );
    println!("\nlayers exercised: Pallas ChaCha20 (L1) → JAX seal_record (L2) → HLO text →");
    println!("PJRT CPU executable → rust crypto pool (L3) → TCP → independent Rust AEAD verify.");
    Ok(())
}
