//! The §3.3 identification workflow, end to end:
//!
//! 1. static analysis — rank functions of nginx + its libraries by AVX
//!    instruction ratio;
//! 2. run the instrumented workload and fold `CORE_POWER.THROTTLE` into
//!    a flame graph;
//! 3. intersect the two: functions that rank high in *both* are the ones
//!    to annotate (`with_avx()`/`without_avx()`);
//! 4. demonstrate the LBR fallback for bursts too short for the counter.
//!
//! ```sh
//! cargo run --release --example identify_avx
//! ```

use avxfreq::analysis::flamegraph::{self, Counter};
use avxfreq::analysis::lbr;
use avxfreq::analysis::static_analysis;
use avxfreq::sched::PolicyKind;
use avxfreq::sim::{MS, SEC};
use avxfreq::workload::crypto::Isa;
use avxfreq::workload::webserver::{build_binaries, run_webserver_machine, stack_table_for, WebCfg};

fn main() -> anyhow::Result<()> {
    let isa = Isa::Avx512;

    // --- stage 1: static analysis --------------------------------------
    println!("### stage 1 — static AVX-ratio analysis (objdump equivalent)\n");
    let bins = build_binaries(isa);
    let rows = static_analysis::analyze(&bins);
    print!("{}", static_analysis::report_table(&rows).render());
    let candidates = static_analysis::candidates(&rows, 0.3);
    println!("\n{} candidate functions above ratio 0.3", candidates.len());

    // --- stage 2: THROTTLE flame graph ----------------------------------
    println!("\n### stage 2 — CORE_POWER.THROTTLE flame graph (instrumented run)\n");
    let mut cfg = WebCfg::paper_default(isa, PolicyKind::Unmodified);
    cfg.track_flame = true;
    cfg.warmup = 300 * MS;
    cfg.measure = SEC;
    let (_run, m) = run_webserver_machine(&cfg);
    let stacks = stack_table_for(isa);
    let folded = flamegraph::fold(&m.flame, &stacks, Counter::Throttle);
    for (stack, v) in folded.iter().take(8) {
        println!("{v:>12}  {stack}");
    }
    std::fs::create_dir_all("results")?;
    std::fs::write(
        "results/throttle_flamegraph.svg",
        flamegraph::render_svg(&folded, "CORE_POWER.THROTTLE — nginx/avx512"),
    )?;
    println!("\nwrote results/throttle_flamegraph.svg");

    // --- stage 3: intersection ------------------------------------------
    println!("\n### stage 3 — intersect static candidates with throttle hits\n");
    let mut to_annotate = Vec::new();
    for c in &candidates {
        let hit = folded.iter().any(|(stack, _)| stack.contains(c.function.as_str()));
        println!(
            "  {:<34} ratio {:.2}  throttle-hit: {}",
            c.function,
            c.avx_ratio,
            if hit { "YES → annotate" } else { "no (memcpy-style false positive)" }
        );
        if hit {
            to_annotate.push(c.function.clone());
        }
    }
    assert!(
        to_annotate.iter().any(|f| f.contains("ChaCha20") || f.contains("poly1305")),
        "workflow must identify the OpenSSL kernels"
    );
    println!(
        "\n→ wrap the SSL entry points calling {:?} in with_avx()/without_avx() (9 lines in nginx)",
        to_annotate
    );

    // --- stage 4: LBR fallback for short bursts -------------------------
    println!("\n### stage 4 — LBR recovery for bursts shorter than the detection window\n");
    let mut trace: Vec<(u64, bool)> = vec![(1, false), (2, false), (777, true)];
    for f in 10..24 {
        trace.push((f, false));
    }
    let attributions = lbr::attribute_trace(&trace, 6);
    for (i, culprit, naive) in attributions {
        println!(
            "  burst at block {i}: naive sample blames fn {naive}, LBR walk finds fn {:?} ✓",
            culprit.unwrap()
        );
    }
    Ok(())
}
